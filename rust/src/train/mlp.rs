//! fp32 multi-layer perceptron with manual backprop — the trained-model
//! source for the quantization flow (MLP half of the paper's examples).

use super::data::Dataset;
use super::rng::Rng;
use crate::onnx::ir::Attr;
use crate::onnx::{batched, GraphBuilder, Model};
use crate::ops::matmul::gemm_f32;
use crate::tensor::{DType, Tensor};

/// Hidden-layer activation — chosen to exercise the paper's Figure 2
/// (ReLU), Figure 4/5 (Tanh) and Figure 6 (Sigmoid) patterns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HiddenAct {
    Relu,
    Tanh,
    Sigmoid,
}

impl HiddenAct {
    fn apply(&self, x: f32) -> f32 {
        match self {
            HiddenAct::Relu => x.max(0.0),
            HiddenAct::Tanh => x.tanh(),
            HiddenAct::Sigmoid => 1.0 / (1.0 + (-x).exp()),
        }
    }

    /// Derivative expressed in terms of the *activated* value `a`.
    fn grad_from_act(&self, a: f32) -> f32 {
        match self {
            HiddenAct::Relu => {
                if a > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            HiddenAct::Tanh => 1.0 - a * a,
            HiddenAct::Sigmoid => a * (1.0 - a),
        }
    }

    fn onnx_op(&self) -> &'static str {
        match self {
            HiddenAct::Relu => "Relu",
            HiddenAct::Tanh => "Tanh",
            HiddenAct::Sigmoid => "Sigmoid",
        }
    }
}

/// One dense layer, weights `[in, out]` row-major (matching ONNX
/// Gemm with transB=0).
#[derive(Clone, Debug)]
pub struct Dense {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub in_dim: usize,
    pub out_dim: usize,
    // momentum buffers
    vw: Vec<f32>,
    vb: Vec<f32>,
}

impl Dense {
    pub fn new(in_dim: usize, out_dim: usize, rng: &mut Rng) -> Dense {
        // He/Xavier-ish init.
        let scale = (2.0 / in_dim as f32).sqrt();
        Dense {
            w: (0..in_dim * out_dim).map(|_| scale * rng.normal()).collect(),
            b: vec![0.0; out_dim],
            in_dim,
            out_dim,
            vw: vec![0.0; in_dim * out_dim],
            vb: vec![0.0; out_dim],
        }
    }
}

/// The MLP: `dims` = [input, hidden..., classes].
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<Dense>,
    pub act: HiddenAct,
}

impl Mlp {
    pub fn new(dims: &[usize], act: HiddenAct, seed: u64) -> Mlp {
        let mut rng = Rng::new(seed);
        let layers = dims
            .windows(2)
            .map(|w| Dense::new(w[0], w[1], &mut rng))
            .collect();
        Mlp { layers, act }
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }

    /// Forward pass over a batch (`n × in_dim`), returning per-layer
    /// activations (activations[0] = input, last = logits).
    fn forward_full(&self, x: &[f32], n: usize) -> Vec<Vec<f32>> {
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        for (li, layer) in self.layers.iter().enumerate() {
            let prev = acts.last().unwrap();
            let mut out = vec![0f32; n * layer.out_dim];
            gemm_f32(prev, &layer.w, n, layer.in_dim, layer.out_dim, &mut out);
            for row in out.chunks_mut(layer.out_dim) {
                for (v, b) in row.iter_mut().zip(&layer.b) {
                    *v += b;
                }
            }
            let is_last = li == self.layers.len() - 1;
            if !is_last {
                for v in &mut out {
                    *v = self.act.apply(*v);
                }
            }
            acts.push(out);
        }
        acts
    }

    /// Logits for a batch.
    pub fn logits(&self, x: &[f32], n: usize) -> Vec<f32> {
        self.forward_full(x, n).pop().unwrap()
    }

    /// Predicted class per row.
    pub fn predict(&self, x: &[f32], n: usize) -> Vec<usize> {
        let logits = self.logits(x, n);
        let c = self.layers.last().unwrap().out_dim;
        logits
            .chunks(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect()
    }

    /// One SGD-with-momentum step on a batch; returns mean CE loss.
    pub fn train_batch(
        &mut self,
        x: &[f32],
        y: &[usize],
        lr: f32,
        momentum: f32,
    ) -> f32 {
        let n = y.len();
        let acts = self.forward_full(x, n);
        let classes = self.layers.last().unwrap().out_dim;
        let logits = acts.last().unwrap();

        // Softmax + CE gradient: dL/dlogit = (p - onehot)/n.
        let mut delta = vec![0f32; n * classes];
        let mut loss = 0f32;
        for i in 0..n {
            let row = &logits[i * classes..(i + 1) * classes];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|&v| (v - max).exp()).collect();
            let sum: f32 = exps.iter().sum();
            for c in 0..classes {
                let p = exps[c] / sum;
                delta[i * classes + c] = (p - if c == y[i] { 1.0 } else { 0.0 }) / n as f32;
                if c == y[i] {
                    loss -= (p.max(1e-12)).ln();
                }
            }
        }
        loss /= n as f32;

        // Backprop through the layers.
        let mut grad_out = delta;
        for li in (0..self.layers.len()).rev() {
            let (in_act, _) = (&acts[li], &acts[li + 1]);
            let layer = &self.layers[li];
            let (id, od) = (layer.in_dim, layer.out_dim);

            // dW = in_act^T @ grad_out ; db = colsum(grad_out)
            let mut dw = vec![0f32; id * od];
            for i in 0..n {
                let a_row = &in_act[i * id..(i + 1) * id];
                let g_row = &grad_out[i * od..(i + 1) * od];
                for (k, &a) in a_row.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let dst = &mut dw[k * od..(k + 1) * od];
                    for (d, &g) in dst.iter_mut().zip(g_row) {
                        *d += a * g;
                    }
                }
            }
            let mut db = vec![0f32; od];
            for g_row in grad_out.chunks(od) {
                for (d, &g) in db.iter_mut().zip(g_row) {
                    *d += g;
                }
            }

            // grad_in = grad_out @ W^T, then through activation.
            let mut grad_in = vec![0f32; n * id];
            for i in 0..n {
                let g_row = &grad_out[i * od..(i + 1) * od];
                let dst = &mut grad_in[i * id..(i + 1) * id];
                for (k, d) in dst.iter_mut().enumerate() {
                    let w_row = &layer.w[k * od..(k + 1) * od];
                    *d = w_row.iter().zip(g_row).map(|(&w, &g)| w * g).sum();
                }
            }
            if li > 0 {
                for (g, &a) in grad_in.iter_mut().zip(in_act.iter()) {
                    *g *= self.act.grad_from_act(a);
                }
            }

            // Momentum update.
            let layer = &mut self.layers[li];
            for ((w, v), d) in layer.w.iter_mut().zip(&mut layer.vw).zip(&dw) {
                *v = momentum * *v - lr * d;
                *w += *v;
            }
            for ((b, v), d) in layer.b.iter_mut().zip(&mut layer.vb).zip(&db) {
                *v = momentum * *v - lr * d;
                *b += *v;
            }
            grad_out = grad_in;
        }
        loss
    }

    /// Export the trained network as an fp32 ONNX model:
    /// Gemm (+activation) chain with a Softmax head.
    pub fn to_model(&self, name: &str) -> Model {
        let mut b = GraphBuilder::new(name);
        let in_dim = self.layers[0].in_dim;
        let classes = self.layers.last().unwrap().out_dim;
        b.input("x", DType::F32, &batched(&[in_dim]));
        let mut cur = "x".to_string();
        for (i, layer) in self.layers.iter().enumerate() {
            let w = b.init(
                &format!("w{i}"),
                Tensor::from_f32(&[layer.in_dim, layer.out_dim], layer.w.clone()).unwrap(),
            );
            let bias = b.init(
                &format!("b{i}"),
                Tensor::from_f32(&[layer.out_dim], layer.b.clone()).unwrap(),
            );
            cur = b.node("Gemm", &[&cur, &w, &bias], &[]);
            if i + 1 < self.layers.len() {
                cur = b.node(self.act.onnx_op(), &[&cur], &[]);
            }
        }
        let sm = b.node("Softmax", &[&cur], &[("axis", Attr::Int(-1))]);
        b.output(&sm, DType::F32, &batched(&[classes]));
        b.finish_model()
    }
}

/// Train a classifier with minibatch SGD; returns per-epoch mean loss.
pub fn train_classifier(
    mlp: &mut Mlp,
    data: &Dataset,
    epochs: usize,
    batch: usize,
    lr: f32,
    momentum: f32,
    seed: u64,
) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut losses = Vec::with_capacity(epochs);
    for _ in 0..epochs {
        let perm = rng.permutation(data.len());
        let mut epoch_loss = 0f32;
        let mut batches = 0usize;
        for chunk in perm.chunks(batch) {
            let mut x = Vec::with_capacity(chunk.len() * data.dim);
            let mut y = Vec::with_capacity(chunk.len());
            for &i in chunk {
                let (xi, yi) = data.sample(i);
                x.extend_from_slice(xi);
                y.push(yi);
            }
            epoch_loss += mlp.train_batch(&x, &y, lr, momentum);
            batches += 1;
        }
        losses.push(epoch_loss / batches.max(1) as f32);
    }
    losses
}

/// Classification accuracy of an MLP on a dataset.
pub fn accuracy(mlp: &Mlp, data: &Dataset) -> f32 {
    let preds = mlp.predict(&data.x, data.len());
    let correct = preds.iter().zip(&data.y).filter(|(p, y)| p == y).count();
    correct as f32 / data.len().max(1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::data::{gaussian_blobs, synthetic_digits};

    #[test]
    fn gradient_check_small_net() {
        // Finite-difference check on a tiny net.
        let mut mlp = Mlp::new(&[3, 4, 2], HiddenAct::Tanh, 1);
        let x = vec![0.5, -0.3, 0.8];
        let y = vec![1usize];

        // Analytic gradient via a zero-momentum, lr=1 "update" trick:
        // capture weights before/after; dw = (before - after) / lr.
        let eps = 1e-3f32;
        let loss_at = |m: &Mlp| -> f32 {
            let logits = m.logits(&x, 1);
            let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = logits.iter().map(|&v| (v - max).exp()).collect();
            let sum: f32 = exps.iter().sum();
            -(exps[1] / sum).max(1e-12).ln()
        };
        let before = mlp.clone();
        let lr = 1e-4;
        mlp.train_batch(&x, &y, lr, 0.0);
        // Check a handful of weights in each layer.
        for li in 0..before.layers.len() {
            for &wi in &[0usize, 1, 3] {
                let analytic = (before.layers[li].w[wi] - mlp.layers[li].w[wi]) / lr;
                let mut plus = before.clone();
                plus.layers[li].w[wi] += eps;
                let mut minus = before.clone();
                minus.layers[li].w[wi] -= eps;
                let numeric = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps);
                assert!(
                    (analytic - numeric).abs() < 2e-2 * numeric.abs().max(1.0),
                    "layer {li} w{wi}: analytic {analytic} vs numeric {numeric}"
                );
            }
        }
    }

    #[test]
    fn learns_blobs() {
        let data = gaussian_blobs(600, 4, 3, 0.3, 7);
        let (train, test) = data.split(0.25, 8);
        let mut mlp = Mlp::new(&[4, 16, 3], HiddenAct::Relu, 2);
        let losses = train_classifier(&mut mlp, &train, 30, 16, 0.05, 0.9, 3);
        assert!(losses.last().unwrap() < &0.2, "loss {:?}", losses.last());
        assert!(accuracy(&mlp, &test) > 0.95);
    }

    #[test]
    fn learns_digits_all_activations() {
        let data = synthetic_digits(1200, 4);
        let (train, test) = data.split(0.2, 5);
        for act in [HiddenAct::Relu, HiddenAct::Tanh, HiddenAct::Sigmoid] {
            let mut mlp = Mlp::new(&[64, 32, 10], act, 6);
            train_classifier(&mut mlp, &train, 25, 32, 0.1, 0.9, 7);
            let acc = accuracy(&mlp, &test);
            assert!(acc > 0.85, "{act:?} accuracy {acc}");
        }
    }

    #[test]
    fn exported_model_matches_forward() {
        let data = synthetic_digits(200, 10);
        let mut mlp = Mlp::new(&[64, 16, 10], HiddenAct::Relu, 11);
        train_classifier(&mut mlp, &data, 5, 32, 0.1, 0.9, 12);
        let model = mlp.to_model("digits_mlp");
        crate::onnx::check_model(&model).unwrap();
        let sess = crate::interp::Session::new(model).unwrap();
        let (x0, _) = data.sample(0);
        let probs = sess
            .run(&[("x", Tensor::from_f32(&[1, 64], x0.to_vec()).unwrap())])
            .unwrap();
        let probs = probs[0].as_f32().unwrap().to_vec();
        // Same argmax as the in-memory net, probabilities sum to 1.
        let logits = mlp.logits(x0, 1);
        let want = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let got = probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(want, got);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }
}
