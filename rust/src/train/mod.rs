//! fp32 training substrate: the source of *really trained* models for the
//! quantization flow. MLP ([`mlp`]) and small CNN ([`cnn`]) with manual
//! backprop, deterministic synthetic datasets ([`data`]), SplitMix64 PRNG
//! ([`rng`]). No external ML dependency — the whole loop is
//! reproducible from a seed.

pub mod cnn;
pub mod data;
pub mod mlp;
pub mod narrow;
pub mod rng;

pub use cnn::{cnn_accuracy, train_cnn, Cnn};
pub use data::{gaussian_blobs, spirals, synthetic_digits, Dataset};
pub use mlp::{accuracy, train_classifier, HiddenAct, Mlp};
pub use narrow::NarrowModel;
pub use rng::Rng;
