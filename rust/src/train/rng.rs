//! Deterministic PRNG (SplitMix64) — no `rand` crate in this offline
//! environment. Used by dataset synthesis, weight init, property tests
//! and benches; determinism keeps every experiment reproducible.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Uniform i8 across the full range.
    pub fn i8(&mut self) -> i8 {
        (self.next_u64() >> 56) as u8 as i8
    }

    /// Fisher–Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
