//! Synthetic datasets.
//!
//! The paper references no public dataset; these generators provide
//! *real trainable workloads* (the e2e example trains to >90% accuracy,
//! so the fp32-vs-int8 accuracy comparison is meaningful) while staying
//! fully deterministic and self-contained.

use super::rng::Rng;

/// A labeled dataset of flat f32 feature vectors.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `n × dim`, row-major.
    pub x: Vec<f32>,
    pub y: Vec<usize>,
    pub dim: usize,
    pub classes: usize,
    /// For image data: (channels, height, width); None for tabular.
    pub image_shape: Option<(usize, usize, usize)>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn sample(&self, i: usize) -> (&[f32], usize) {
        (&self.x[i * self.dim..(i + 1) * self.dim], self.y[i])
    }

    /// Split into (train, test) by a deterministic shuffle.
    pub fn split(&self, test_fraction: f32, seed: u64) -> (Dataset, Dataset) {
        let mut rng = Rng::new(seed);
        let perm = rng.permutation(self.len());
        let n_test = (self.len() as f32 * test_fraction) as usize;
        let mk = |idx: &[usize]| {
            let mut x = Vec::with_capacity(idx.len() * self.dim);
            let mut y = Vec::with_capacity(idx.len());
            for &i in idx {
                x.extend_from_slice(&self.x[i * self.dim..(i + 1) * self.dim]);
                y.push(self.y[i]);
            }
            Dataset {
                x,
                y,
                dim: self.dim,
                classes: self.classes,
                image_shape: self.image_shape,
            }
        };
        (mk(&perm[n_test..]), mk(&perm[..n_test]))
    }
}

/// 8×8 digit stencils (a compact synthetic stand-in for sklearn-digits).
/// Each row is one digit 0-9 as an 8-byte-per-row bitmap.
const DIGIT_STENCILS: [[u8; 8]; 10] = [
    [0x3C, 0x66, 0x66, 0x66, 0x66, 0x66, 0x66, 0x3C], // 0
    [0x18, 0x38, 0x18, 0x18, 0x18, 0x18, 0x18, 0x3C], // 1
    [0x3C, 0x66, 0x06, 0x0C, 0x18, 0x30, 0x60, 0x7E], // 2
    [0x3C, 0x66, 0x06, 0x1C, 0x06, 0x06, 0x66, 0x3C], // 3
    [0x0C, 0x1C, 0x3C, 0x6C, 0x7E, 0x0C, 0x0C, 0x0C], // 4
    [0x7E, 0x60, 0x60, 0x7C, 0x06, 0x06, 0x66, 0x3C], // 5
    [0x3C, 0x66, 0x60, 0x7C, 0x66, 0x66, 0x66, 0x3C], // 6
    [0x7E, 0x06, 0x0C, 0x0C, 0x18, 0x18, 0x30, 0x30], // 7
    [0x3C, 0x66, 0x66, 0x3C, 0x66, 0x66, 0x66, 0x3C], // 8
    [0x3C, 0x66, 0x66, 0x66, 0x3E, 0x06, 0x66, 0x3C], // 9
];

/// Synthetic 8×8 grayscale digits: stencil + sub-pixel jitter, random
/// shift (±1 px), per-pixel noise, random contrast. Hard enough that a
/// linear model does not saturate, easy enough to train in seconds.
pub fn synthetic_digits(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let dim = 64;
    let mut x = Vec::with_capacity(n * dim);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let digit = rng.below(10);
        let stencil = &DIGIT_STENCILS[digit];
        let dx = rng.below(3) as isize - 1;
        let dy = rng.below(3) as isize - 1;
        let contrast = rng.range_f32(0.7, 1.3);
        let noise = rng.range_f32(0.05, 0.25);
        for r in 0..8usize {
            for c in 0..8usize {
                let sr = r as isize - dy;
                let sc = c as isize - dx;
                let lit = if (0..8).contains(&sr) && (0..8).contains(&sc) {
                    (stencil[sr as usize] >> (7 - sc as usize)) & 1 == 1
                } else {
                    false
                };
                let base = if lit { contrast } else { 0.0 };
                let v = (base + noise * rng.normal()).clamp(-0.5, 1.5);
                x.push(v);
            }
        }
        y.push(digit);
    }
    Dataset {
        x,
        y,
        dim,
        classes: 10,
        image_shape: Some((1, 8, 8)),
    }
}

/// Gaussian blobs: `classes` isotropic clusters in `dim` dimensions.
pub fn gaussian_blobs(n: usize, dim: usize, classes: usize, spread: f32, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    // Random but well-separated centers.
    let centers: Vec<f32> = (0..classes * dim).map(|_| rng.range_f32(-2.0, 2.0)).collect();
    let mut x = Vec::with_capacity(n * dim);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let cls = rng.below(classes);
        for d in 0..dim {
            x.push(centers[cls * dim + d] + spread * rng.normal());
        }
        y.push(cls);
    }
    Dataset {
        x,
        y,
        dim,
        classes,
        image_shape: None,
    }
}

/// Two interleaved spirals — a classic nonlinear benchmark exercising
/// the Tanh/Sigmoid activation patterns (Figs. 4–6).
pub fn spirals(n: usize, noise: f32, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * 2);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let cls = i % 2;
        let t = rng.uniform() * 3.0 * std::f32::consts::PI + 0.5;
        let r = t / (3.0 * std::f32::consts::PI) * 2.0;
        let phase = if cls == 0 { 0.0 } else { std::f32::consts::PI };
        x.push(r * (t + phase).cos() + noise * rng.normal());
        x.push(r * (t + phase).sin() + noise * rng.normal());
        y.push(cls);
    }
    Dataset {
        x,
        y,
        dim: 2,
        classes: 2,
        image_shape: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_shapes_and_labels() {
        let d = synthetic_digits(500, 1);
        assert_eq!(d.len(), 500);
        assert_eq!(d.dim, 64);
        assert!(d.y.iter().all(|&c| c < 10));
        // All ten classes present in 500 samples.
        for cls in 0..10 {
            assert!(d.y.contains(&cls), "class {cls} missing");
        }
    }

    #[test]
    fn digits_deterministic() {
        let a = synthetic_digits(50, 9);
        let b = synthetic_digits(50, 9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn split_partitions() {
        let d = synthetic_digits(100, 2);
        let (tr, te) = d.split(0.2, 3);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
    }

    #[test]
    fn blobs_separated() {
        let d = gaussian_blobs(200, 4, 3, 0.1, 5);
        assert_eq!(d.classes, 3);
        assert_eq!(d.dim, 4);
    }

    #[test]
    fn spirals_two_classes() {
        let d = spirals(100, 0.01, 6);
        assert!(d.y.iter().filter(|&&c| c == 0).count() > 30);
        assert!(d.y.iter().filter(|&&c| c == 1).count() > 30);
    }
}
