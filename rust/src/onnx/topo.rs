//! Topological scheduling of graph nodes with cycle detection.

use super::ir::Graph;
use std::collections::{HashMap, HashSet};
use thiserror::Error;

#[derive(Error, Debug)]
pub enum TopoError {
    #[error("graph contains a cycle involving node '{0}'")]
    Cycle(String),
    #[error("value '{value}' consumed by node '{node}' has no producer, initializer or graph input")]
    Undefined { value: String, node: String },
    #[error("value '{0}' is produced more than once")]
    Redefined(String),
}

/// Return indices into `graph.nodes` in a valid execution order.
///
/// Every node input must be a graph input, an initializer, or the output
/// of an earlier node. Kahn's algorithm; ties broken by authoring order so
/// scheduling is deterministic.
pub fn topo_order(graph: &Graph) -> Result<Vec<usize>, TopoError> {
    let mut available: HashSet<&str> = HashSet::new();
    for vi in &graph.inputs {
        available.insert(&vi.name);
    }
    for (name, _) in &graph.initializers {
        available.insert(name);
    }

    // Producer map + duplicate-definition check.
    let mut producer: HashMap<&str, usize> = HashMap::new();
    for (i, n) in graph.nodes.iter().enumerate() {
        for o in &n.outputs {
            if o.is_empty() {
                continue;
            }
            if available.contains(o.as_str()) || producer.insert(o, i).is_some() {
                return Err(TopoError::Redefined(o.clone()));
            }
        }
    }

    // Validate all consumed values exist somewhere.
    for n in &graph.nodes {
        for i in &n.inputs {
            if i.is_empty() {
                continue; // omitted optional input
            }
            if !available.contains(i.as_str()) && !producer.contains_key(i.as_str()) {
                return Err(TopoError::Undefined {
                    value: i.clone(),
                    node: n.name.clone(),
                });
            }
        }
    }

    let n_nodes = graph.nodes.len();
    let mut scheduled = vec![false; n_nodes];
    let mut order = Vec::with_capacity(n_nodes);
    // O(V*E) worst case; fine at our graph sizes (tens of nodes) and keeps
    // the deterministic authoring-order tie-break trivially correct.
    loop {
        let mut progressed = false;
        for (i, node) in graph.nodes.iter().enumerate() {
            if scheduled[i] {
                continue;
            }
            let ready = node.inputs.iter().all(|inp| {
                inp.is_empty()
                    || available.contains(inp.as_str())
                    || producer
                        .get(inp.as_str())
                        .map(|&p| scheduled[p])
                        .unwrap_or(false)
            });
            if ready {
                scheduled[i] = true;
                order.push(i);
                progressed = true;
            }
        }
        if order.len() == n_nodes {
            return Ok(order);
        }
        if !progressed {
            let stuck = graph
                .nodes
                .iter()
                .enumerate()
                .find(|(i, _)| !scheduled[*i])
                .map(|(_, n)| n.name.clone())
                .unwrap_or_default();
            return Err(TopoError::Cycle(stuck));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::ir::{Graph, Node, ValueInfo};
    use crate::tensor::DType;

    fn graph_with(nodes: Vec<Node>) -> Graph {
        let mut g = Graph {
            name: "t".into(),
            ..Default::default()
        };
        g.inputs.push(ValueInfo::fixed("x", DType::F32, &[1]));
        g.nodes = nodes;
        g
    }

    #[test]
    fn orders_out_of_order_authorship() {
        // b depends on a, authored in reverse.
        let g = graph_with(vec![
            Node::new("b", "Relu", &["a_out"], &["b_out"]),
            Node::new("a", "Relu", &["x"], &["a_out"]),
        ]);
        assert_eq!(topo_order(&g).unwrap(), vec![1, 0]);
    }

    #[test]
    fn detects_cycle() {
        let g = graph_with(vec![
            Node::new("a", "Add", &["x", "b_out"], &["a_out"]),
            Node::new("b", "Relu", &["a_out"], &["b_out"]),
        ]);
        assert!(matches!(topo_order(&g), Err(TopoError::Cycle(_))));
    }

    #[test]
    fn detects_undefined_input() {
        let g = graph_with(vec![Node::new("a", "Relu", &["ghost"], &["a_out"])]);
        assert!(matches!(topo_order(&g), Err(TopoError::Undefined { .. })));
    }

    #[test]
    fn detects_redefinition() {
        let g = graph_with(vec![
            Node::new("a", "Relu", &["x"], &["y"]),
            Node::new("b", "Relu", &["x"], &["y"]),
        ]);
        assert!(matches!(topo_order(&g), Err(TopoError::Redefined(_))));
    }

    #[test]
    fn optional_empty_inputs_skipped() {
        let g = graph_with(vec![Node::new(
            "mm",
            "MatMulInteger",
            &["x", "x", ""],
            &["y"],
        )]);
        assert_eq!(topo_order(&g).unwrap(), vec![0]);
    }
}
