//! Minimal self-contained JSON reader/writer.
//!
//! `serde`/`serde_json` are not available in this offline environment, so
//! the model text format is implemented directly. Numbers are kept as
//! their canonical source text inside [`Json::Num`], which makes f32
//! round-trips bit-exact (Rust's shortest-representation float formatting
//! is guaranteed to re-parse to the identical value) — a requirement for
//! the paper's "narrow margins" goal: serializing a model must not perturb
//! any quantization parameter.

use std::fmt::Write as _;
use thiserror::Error;

/// A JSON document node.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Number kept as canonical text (exactness; see module docs).
    Num(String),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Error, Debug)]
pub enum JsonError {
    #[error("unexpected end of input at byte {0}")]
    Eof(usize),
    #[error("unexpected character '{ch}' at byte {pos}")]
    Unexpected { ch: char, pos: usize },
    #[error("invalid number literal '{0}'")]
    BadNumber(String),
    #[error("invalid escape sequence at byte {0}")]
    BadEscape(usize),
    #[error("expected {expected} at byte {pos}")]
    Expected { expected: &'static str, pos: usize },
    #[error("trailing data at byte {0}")]
    Trailing(usize),
}

impl Json {
    pub fn num_i64(v: i64) -> Json {
        Json::Num(v.to_string())
    }
    pub fn num_usize(v: usize) -> Json {
        Json::Num(v.to_string())
    }
    /// Shortest round-trip f32 formatting (exact re-parse guaranteed).
    pub fn num_f32(v: f32) -> Json {
        if v.is_finite() {
            Json::Num(format!("{v}"))
        } else {
            // JSON has no inf/nan literals; encode as strings.
            Json::Str(format!("{v}"))
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj()?.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
    pub fn to_i64(&self) -> Option<i64> {
        match self {
            Json::Num(s) => s.parse().ok(),
            _ => None,
        }
    }
    pub fn to_usize(&self) -> Option<usize> {
        match self {
            Json::Num(s) => s.parse().ok(),
            _ => None,
        }
    }
    pub fn to_f32(&self) -> Option<f32> {
        match self {
            Json::Num(s) => s.parse().ok(),
            // inf/nan encoded as strings by num_f32.
            Json::Str(s) => match s.as_str() {
                "inf" => Some(f32::INFINITY),
                "-inf" => Some(f32::NEG_INFINITY),
                "NaN" => Some(f32::NAN),
                _ => None,
            },
            _ => None,
        }
    }
    pub fn to_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(s) => out.push_str(s),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(v) => {
                out.push('{');
                for (i, (k, item)) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    item.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(JsonError::Trailing(pos));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err(JsonError::Eof(*pos));
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b'-' | b'0'..=b'9' => parse_num(b, pos),
        c => Err(JsonError::Unexpected {
            ch: c as char,
            pos: *pos,
        }),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &'static str, v: Json) -> Result<Json, JsonError> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(JsonError::Expected {
            expected: lit,
            pos: *pos,
        })
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    let start = *pos;
    if b[*pos] == b'-' {
        *pos += 1;
    }
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).unwrap();
    // Validate it parses as a number.
    if text.parse::<f64>().is_err() {
        return Err(JsonError::BadNumber(text.to_string()));
    }
    Ok(Json::Num(text.to_string()))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            return Err(JsonError::Eof(*pos));
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    return Err(JsonError::Eof(*pos));
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err(JsonError::Eof(*pos));
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| JsonError::BadEscape(*pos))?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| JsonError::BadEscape(*pos))?;
                        out.push(char::from_u32(code).ok_or(JsonError::BadEscape(*pos))?);
                        *pos += 4;
                    }
                    _ => return Err(JsonError::BadEscape(*pos)),
                }
                *pos += 1;
            }
            _ => {
                // Consume one UTF-8 scalar.
                let s = std::str::from_utf8(&b[*pos..]).map_err(|_| JsonError::BadEscape(*pos))?;
                let c = s.chars().next().ok_or(JsonError::Eof(*pos))?;
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos >= b.len() {
            return Err(JsonError::Eof(*pos));
        }
        match b[*pos] {
            b',' => {
                *pos += 1;
            }
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            c => {
                return Err(JsonError::Unexpected {
                    ch: c as char,
                    pos: *pos,
                })
            }
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1; // '{'
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(items));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(JsonError::Expected {
                expected: "object key",
                pos: *pos,
            });
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b':' {
            return Err(JsonError::Expected {
                expected: ":",
                pos: *pos,
            });
        }
        *pos += 1;
        let value = parse_value(b, pos)?;
        items.push((key, value));
        skip_ws(b, pos);
        if *pos >= b.len() {
            return Err(JsonError::Eof(*pos));
        }
        match b[*pos] {
            b',' => {
                *pos += 1;
            }
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(items));
            }
            c => {
                return Err(JsonError::Unexpected {
                    ch: c as char,
                    pos: *pos,
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_basic() {
        let src = r#"{"a":[1,2.5,-3],"b":"hi","c":true,"d":null,"e":{}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("c").unwrap().to_bool(), Some(true));
    }

    #[test]
    fn f32_exact_round_trip() {
        // Every one of these must survive format -> parse bit-exactly.
        for &x in &[
            0.1f32,
            1.0 / 3.0,
            f32::MIN_POSITIVE,
            1.1754944e-38,
            3.4028235e38,
            -0.0,
            6.1035156e-5,
        ] {
            let j = Json::num_f32(x);
            let y = Json::parse(&j.to_string()).unwrap().to_f32().unwrap();
            assert_eq!(x.to_bits(), y.to_bits(), "lost bits for {x}");
        }
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\tπ".to_string());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn nested() {
        let src = r#"[[1,[2,[3]]],{"k":[{"m":0}]}]"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string(), src);
    }
}
