//! Model <-> JSON text serialization.
//!
//! The serialized form carries everything the paper's goal 1 requires to
//! live *inside* the model: weights, biases, and the quantization
//! parameters (`Quant_scale`, `Quant_shift`, QuantizeLinear scales and
//! zero-points) as ordinary initializers. Floats are stored in shortest
//! round-trip decimal (bit-exact re-parse), f16 as raw bit patterns.

use super::ir::{Attr, Dim, Graph, Model, Node, ValueInfo};
use super::json::Json;
use crate::tensor::{f16::F16, DType, Tensor, TensorData};
use thiserror::Error;

#[derive(Error, Debug)]
pub enum SerdeError {
    #[error("json: {0}")]
    Json(#[from] super::json::JsonError),
    #[error("missing field '{0}'")]
    Missing(&'static str),
    #[error("bad field '{field}': {msg}")]
    Bad { field: &'static str, msg: String },
    #[error("tensor: {0}")]
    Tensor(#[from] crate::tensor::TensorError),
}

fn bad(field: &'static str, msg: impl Into<String>) -> SerdeError {
    SerdeError::Bad {
        field,
        msg: msg.into(),
    }
}

// --- serialization --------------------------------------------------------

fn dims_to_json(dims: &[Dim]) -> Json {
    Json::Arr(
        dims.iter()
            .map(|d| match d {
                Dim::Fixed(n) => Json::num_usize(*n),
                Dim::Symbolic(s) => Json::Str(s.clone()),
            })
            .collect(),
    )
}

fn value_info_to_json(vi: &ValueInfo) -> Json {
    Json::Obj(vec![
        ("name".into(), Json::Str(vi.name.clone())),
        ("dtype".into(), Json::Str(vi.dtype.onnx_name().into())),
        ("shape".into(), dims_to_json(&vi.shape)),
    ])
}

fn tensor_data_to_json(t: &Tensor) -> Json {
    match t.data() {
        TensorData::F32(v) => Json::Arr(v.iter().map(|&x| Json::num_f32(x)).collect()),
        // f16 serialized as raw bits — exact by construction.
        TensorData::F16(v) => Json::Arr(v.iter().map(|x| Json::num_i64(x.0 as i64)).collect()),
        TensorData::I8(v) => Json::Arr(v.iter().map(|&x| Json::num_i64(x as i64)).collect()),
        TensorData::U8(v) => Json::Arr(v.iter().map(|&x| Json::num_i64(x as i64)).collect()),
        TensorData::I32(v) => Json::Arr(v.iter().map(|&x| Json::num_i64(x as i64)).collect()),
        TensorData::I64(v) => Json::Arr(v.iter().map(|&x| Json::num_i64(x)).collect()),
        TensorData::Bool(v) => Json::Arr(v.iter().map(|&x| Json::Bool(x)).collect()),
    }
}

fn tensor_to_json(name: &str, t: &Tensor) -> Json {
    Json::Obj(vec![
        ("name".into(), Json::Str(name.to_string())),
        ("dtype".into(), Json::Str(t.dtype().onnx_name().into())),
        (
            "shape".into(),
            Json::Arr(t.shape().iter().map(|&d| Json::num_usize(d)).collect()),
        ),
        ("data".into(), tensor_data_to_json(t)),
    ])
}

fn attr_to_json(a: &Attr) -> Json {
    let (kind, value) = match a {
        Attr::Int(v) => ("int", Json::num_i64(*v)),
        Attr::Ints(v) => (
            "ints",
            Json::Arr(v.iter().map(|&x| Json::num_i64(x)).collect()),
        ),
        Attr::Float(v) => ("float", Json::num_f32(*v)),
        Attr::Floats(v) => (
            "floats",
            Json::Arr(v.iter().map(|&x| Json::num_f32(x)).collect()),
        ),
        Attr::Str(v) => ("string", Json::Str(v.clone())),
        Attr::Tensor(t) => ("tensor", tensor_to_json("", t)),
    };
    Json::Obj(vec![
        ("kind".into(), Json::Str(kind.into())),
        ("value".into(), value),
    ])
}

fn node_to_json(n: &Node) -> Json {
    Json::Obj(vec![
        ("name".into(), Json::Str(n.name.clone())),
        ("op".into(), Json::Str(n.op_type.clone())),
        (
            "inputs".into(),
            Json::Arr(n.inputs.iter().map(|s| Json::Str(s.clone())).collect()),
        ),
        (
            "outputs".into(),
            Json::Arr(n.outputs.iter().map(|s| Json::Str(s.clone())).collect()),
        ),
        (
            "attrs".into(),
            Json::Obj(
                n.attributes
                    .iter()
                    .map(|(k, v)| (k.clone(), attr_to_json(v)))
                    .collect(),
            ),
        ),
    ])
}

/// Serialize a model to its JSON text form.
pub fn model_to_json(m: &Model) -> String {
    let graph = Json::Obj(vec![
        ("name".into(), Json::Str(m.graph.name.clone())),
        (
            "inputs".into(),
            Json::Arr(m.graph.inputs.iter().map(value_info_to_json).collect()),
        ),
        (
            "outputs".into(),
            Json::Arr(m.graph.outputs.iter().map(value_info_to_json).collect()),
        ),
        (
            "initializers".into(),
            Json::Arr(
                m.graph
                    .initializers
                    .iter()
                    .map(|(n, t)| tensor_to_json(n, t))
                    .collect(),
            ),
        ),
        (
            "nodes".into(),
            Json::Arr(m.graph.nodes.iter().map(node_to_json).collect()),
        ),
    ]);
    Json::Obj(vec![
        ("ir_version".into(), Json::num_i64(m.ir_version)),
        ("opset_version".into(), Json::num_i64(m.opset_version)),
        ("producer_name".into(), Json::Str(m.producer_name.clone())),
        ("doc".into(), Json::Str(m.doc.clone())),
        (
            "metadata".into(),
            Json::Arr(
                m.metadata
                    .iter()
                    .map(|(k, v)| {
                        Json::Arr(vec![Json::Str(k.clone()), Json::Str(v.clone())])
                    })
                    .collect(),
            ),
        ),
        ("graph".into(), graph),
    ])
    .to_string()
}

// --- deserialization ------------------------------------------------------

fn parse_dtype(j: &Json, field: &'static str) -> Result<DType, SerdeError> {
    let s = j.as_str().ok_or(bad(field, "dtype must be a string"))?;
    DType::from_onnx_name(s).ok_or(bad(field, format!("unknown dtype '{s}'")))
}

fn parse_dims(j: &Json) -> Result<Vec<Dim>, SerdeError> {
    j.as_arr()
        .ok_or(bad("shape", "must be array"))?
        .iter()
        .map(|d| match d {
            Json::Str(s) => Ok(Dim::Symbolic(s.clone())),
            n => n
                .to_usize()
                .map(Dim::Fixed)
                .ok_or(bad("shape", "dim must be usize or string")),
        })
        .collect()
}

fn parse_value_info(j: &Json) -> Result<ValueInfo, SerdeError> {
    Ok(ValueInfo {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .ok_or(SerdeError::Missing("name"))?
            .to_string(),
        dtype: parse_dtype(j.get("dtype").ok_or(SerdeError::Missing("dtype"))?, "dtype")?,
        shape: parse_dims(j.get("shape").ok_or(SerdeError::Missing("shape"))?)?,
    })
}

fn parse_tensor(j: &Json) -> Result<(String, Tensor), SerdeError> {
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .ok_or(SerdeError::Missing("name"))?
        .to_string();
    let dtype = parse_dtype(j.get("dtype").ok_or(SerdeError::Missing("dtype"))?, "dtype")?;
    let shape: Vec<usize> = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or(SerdeError::Missing("shape"))?
        .iter()
        .map(|d| d.to_usize().ok_or(bad("shape", "dim must be usize")))
        .collect::<Result<_, _>>()?;
    let data = j
        .get("data")
        .and_then(Json::as_arr)
        .ok_or(SerdeError::Missing("data"))?;
    let want = |msg: &'static str| bad("data", msg);
    let td = match dtype {
        DType::F32 => TensorData::F32(
            data.iter()
                .map(|v| v.to_f32().ok_or(want("f32")))
                .collect::<Result<_, _>>()?,
        ),
        DType::F16 => TensorData::F16(
            data.iter()
                .map(|v| {
                    v.to_i64()
                        .and_then(|b| u16::try_from(b).ok())
                        .map(F16)
                        .ok_or(want("f16 bits"))
                })
                .collect::<Result<_, _>>()?,
        ),
        DType::I8 => TensorData::I8(
            data.iter()
                .map(|v| {
                    v.to_i64()
                        .and_then(|b| i8::try_from(b).ok())
                        .ok_or(want("i8"))
                })
                .collect::<Result<_, _>>()?,
        ),
        DType::U8 => TensorData::U8(
            data.iter()
                .map(|v| {
                    v.to_i64()
                        .and_then(|b| u8::try_from(b).ok())
                        .ok_or(want("u8"))
                })
                .collect::<Result<_, _>>()?,
        ),
        DType::I32 => TensorData::I32(
            data.iter()
                .map(|v| {
                    v.to_i64()
                        .and_then(|b| i32::try_from(b).ok())
                        .ok_or(want("i32"))
                })
                .collect::<Result<_, _>>()?,
        ),
        DType::I64 => TensorData::I64(
            data.iter()
                .map(|v| v.to_i64().ok_or(want("i64")))
                .collect::<Result<_, _>>()?,
        ),
        DType::Bool => TensorData::Bool(
            data.iter()
                .map(|v| v.to_bool().ok_or(want("bool")))
                .collect::<Result<_, _>>()?,
        ),
    };
    Ok((name, Tensor::new(shape, td)?))
}

fn parse_attr(j: &Json) -> Result<Attr, SerdeError> {
    let kind = j
        .get("kind")
        .and_then(Json::as_str)
        .ok_or(SerdeError::Missing("kind"))?;
    let value = j.get("value").ok_or(SerdeError::Missing("value"))?;
    Ok(match kind {
        "int" => Attr::Int(value.to_i64().ok_or(bad("value", "int"))?),
        "ints" => Attr::Ints(
            value
                .as_arr()
                .ok_or(bad("value", "ints"))?
                .iter()
                .map(|v| v.to_i64().ok_or(bad("value", "ints item")))
                .collect::<Result<_, _>>()?,
        ),
        "float" => Attr::Float(value.to_f32().ok_or(bad("value", "float"))?),
        "floats" => Attr::Floats(
            value
                .as_arr()
                .ok_or(bad("value", "floats"))?
                .iter()
                .map(|v| v.to_f32().ok_or(bad("value", "floats item")))
                .collect::<Result<_, _>>()?,
        ),
        "string" => Attr::Str(value.as_str().ok_or(bad("value", "string"))?.to_string()),
        "tensor" => Attr::Tensor(parse_tensor(value)?.1),
        other => return Err(bad("kind", format!("unknown attr kind '{other}'"))),
    })
}

fn parse_node(j: &Json) -> Result<Node, SerdeError> {
    let names = |key: &'static str| -> Result<Vec<String>, SerdeError> {
        j.get(key)
            .and_then(Json::as_arr)
            .ok_or(SerdeError::Missing(key))?
            .iter()
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .ok_or(bad("inputs/outputs", "must be strings"))
            })
            .collect()
    };
    let mut node = Node {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        op_type: j
            .get("op")
            .and_then(Json::as_str)
            .ok_or(SerdeError::Missing("op"))?
            .to_string(),
        inputs: names("inputs")?,
        outputs: names("outputs")?,
        attributes: Default::default(),
    };
    if let Some(attrs) = j.get("attrs").and_then(Json::as_obj) {
        for (k, v) in attrs {
            node.attributes.insert(k.clone(), parse_attr(v)?);
        }
    }
    Ok(node)
}

/// Parse a model from its JSON text form.
pub fn model_from_json(text: &str) -> Result<Model, SerdeError> {
    let j = Json::parse(text)?;
    let g = j.get("graph").ok_or(SerdeError::Missing("graph"))?;
    let arr = |key: &'static str| -> Result<&[Json], SerdeError> {
        g.get(key)
            .and_then(Json::as_arr)
            .ok_or(SerdeError::Missing(key))
    };
    let graph = Graph {
        name: g
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        inputs: arr("inputs")?
            .iter()
            .map(parse_value_info)
            .collect::<Result<_, _>>()?,
        outputs: arr("outputs")?
            .iter()
            .map(parse_value_info)
            .collect::<Result<_, _>>()?,
        initializers: arr("initializers")?
            .iter()
            .map(parse_tensor)
            .collect::<Result<_, _>>()?,
        nodes: arr("nodes")?
            .iter()
            .map(parse_node)
            .collect::<Result<_, _>>()?,
    };
    Ok(Model {
        ir_version: j
            .get("ir_version")
            .and_then(Json::to_i64)
            .ok_or(SerdeError::Missing("ir_version"))?,
        opset_version: j
            .get("opset_version")
            .and_then(Json::to_i64)
            .ok_or(SerdeError::Missing("opset_version"))?,
        producer_name: j
            .get("producer_name")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        doc: j
            .get("doc")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        metadata: j
            .get("metadata")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|pair| {
                let a = pair.as_arr()?;
                Some((a.first()?.as_str()?.to_string(), a.get(1)?.as_str()?.to_string()))
            })
            .collect(),
        graph,
    })
}

/// Write a model to a file.
pub fn save_model(m: &Model, path: &std::path::Path) -> anyhow::Result<()> {
    std::fs::write(path, model_to_json(m))?;
    Ok(())
}

/// Read a model from a file.
pub fn load_model(path: &std::path::Path) -> anyhow::Result<Model> {
    let text = std::fs::read_to_string(path)?;
    Ok(model_from_json(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::ir::{Attr, Dim, Graph, Model, Node, ValueInfo};
    use crate::tensor::Tensor;

    fn sample_model() -> Model {
        let mut g = Graph {
            name: "fc".into(),
            ..Default::default()
        };
        g.inputs.push(ValueInfo::new(
            "x",
            DType::I8,
            &[Dim::Symbolic("N".into()), Dim::Fixed(4)],
        ));
        g.outputs.push(ValueInfo::new(
            "y",
            DType::I8,
            &[Dim::Symbolic("N".into()), Dim::Fixed(2)],
        ));
        g.initializers.push((
            "w".into(),
            Tensor::from_i8(&[4, 2], vec![1, -2, 3, -4, 5, -6, 7, -8]).unwrap(),
        ));
        g.initializers
            .push(("qs".into(), Tensor::scalar_f32(11184810.0)));
        g.initializers.push((
            "h".into(),
            Tensor::from_f16(&[2], vec![F16::from_f32(0.5), F16::NAN]).unwrap(),
        ));
        g.nodes.push(
            Node::new("mm", "MatMulInteger", &["x", "w"], &["acc"])
                .with_attr("doc", Attr::Str("eq5".into())),
        );
        g.nodes.push(
            Node::new("mul", "Mul", &["acc_f", "qs"], &["y_f"])
                .with_attr("k", Attr::Floats(vec![0.1, 1.0 / 3.0])),
        );
        Model::new(g)
    }

    #[test]
    fn model_round_trip() {
        let m = sample_model();
        let text = model_to_json(&m);
        let back = model_from_json(&text).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn round_trip_is_stable() {
        // Serializing twice yields identical text (canonical form).
        let m = sample_model();
        let t1 = model_to_json(&m);
        let t2 = model_to_json(&model_from_json(&t1).unwrap());
        assert_eq!(t1, t2);
    }

    #[test]
    fn quant_scale_float_precision() {
        // The paper's 1/3 example: Quant_scale = 11184810 stored as FLOAT
        // must survive serialization exactly.
        let m = sample_model();
        let back = model_from_json(&model_to_json(&m)).unwrap();
        let qs = back.graph.initializer("qs").unwrap();
        assert_eq!(qs.as_f32().unwrap()[0], 11184810.0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(model_from_json("{}").is_err());
        assert!(model_from_json("not json").is_err());
        assert!(model_from_json(r#"{"graph":{"name":"g"}}"#).is_err());
    }
}
