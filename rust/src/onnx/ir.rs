//! The ONNX-compatible intermediate representation.
//!
//! Mirrors the ONNX object model — `Model` / `Graph` / `Node` /
//! `Attribute` / initializer tensors / `ValueInfo` — with the operator
//! *semantics* of the standard opset. The wire format is our own JSON
//! text serialization ([`super::json`]); see DESIGN.md §3 for why that
//! substitution is faithful (the paper's methodology depends on the
//! object model and standard-operator semantics, not on protobuf bytes).

use crate::tensor::{DType, Tensor};
use std::collections::BTreeMap;

/// A node attribute, matching ONNX `AttributeProto` kinds we need.
#[derive(Clone, Debug, PartialEq)]
pub enum Attr {
    Int(i64),
    Ints(Vec<i64>),
    Float(f32),
    Floats(Vec<f32>),
    Str(String),
    Tensor(Tensor),
}

impl Attr {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Attr::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_ints(&self) -> Option<&[i64]> {
        match self {
            Attr::Ints(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f32> {
        match self {
            Attr::Float(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Attr::Str(v) => Some(v),
            _ => None,
        }
    }
}

/// One operator invocation in the graph. `inputs`/`outputs` are value
/// names; an empty input name denotes an omitted optional input (ONNX
/// convention).
#[derive(Clone, Debug, PartialEq)]
pub struct Node {
    pub name: String,
    pub op_type: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    pub attributes: BTreeMap<String, Attr>,
}

impl Node {
    pub fn new(name: &str, op_type: &str, inputs: &[&str], outputs: &[&str]) -> Node {
        Node {
            name: name.to_string(),
            op_type: op_type.to_string(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
            attributes: BTreeMap::new(),
        }
    }

    pub fn with_attr(mut self, key: &str, attr: Attr) -> Node {
        self.attributes.insert(key.to_string(), attr);
        self
    }

    pub fn attr(&self, key: &str) -> Option<&Attr> {
        self.attributes.get(key)
    }

    pub fn attr_int(&self, key: &str) -> Option<i64> {
        self.attr(key).and_then(Attr::as_int)
    }

    pub fn attr_ints(&self, key: &str) -> Option<&[i64]> {
        self.attr(key).and_then(Attr::as_ints)
    }

    pub fn attr_float(&self, key: &str) -> Option<f32> {
        self.attr(key).and_then(Attr::as_float)
    }

    pub fn attr_str(&self, key: &str) -> Option<&str> {
        self.attr(key).and_then(Attr::as_str)
    }
}

/// A dimension: fixed, or symbolic (e.g. the batch axis `"N"`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Dim {
    Fixed(usize),
    Symbolic(String),
}

impl Dim {
    pub fn fixed(&self) -> Option<usize> {
        match self {
            Dim::Fixed(n) => Some(*n),
            Dim::Symbolic(_) => None,
        }
    }
}

/// Typed shape signature of a graph input/output (`ValueInfoProto`).
#[derive(Clone, Debug, PartialEq)]
pub struct ValueInfo {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<Dim>,
}

impl ValueInfo {
    pub fn new(name: &str, dtype: DType, dims: &[Dim]) -> ValueInfo {
        ValueInfo {
            name: name.to_string(),
            dtype,
            shape: dims.to_vec(),
        }
    }

    /// All-fixed shape helper.
    pub fn fixed(name: &str, dtype: DType, shape: &[usize]) -> ValueInfo {
        ValueInfo {
            name: name.to_string(),
            dtype,
            shape: shape.iter().map(|&d| Dim::Fixed(d)).collect(),
        }
    }

    /// Concrete shape if every dim is fixed.
    pub fn fixed_shape(&self) -> Option<Vec<usize>> {
        self.shape.iter().map(Dim::fixed).collect()
    }

    /// Resolve symbolic dims using a binding map (e.g. {"N": 8}).
    pub fn resolve_shape(&self, bindings: &BTreeMap<String, usize>) -> Option<Vec<usize>> {
        self.shape
            .iter()
            .map(|d| match d {
                Dim::Fixed(n) => Some(*n),
                Dim::Symbolic(s) => bindings.get(s).copied(),
            })
            .collect()
    }
}

/// The computation graph: nodes in topological order of authorship
/// (the checker/scheduler re-verifies), named initializers (weights,
/// biases and — centrally for this paper — the embedded quantization
/// parameters), and typed inputs/outputs.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub inputs: Vec<ValueInfo>,
    pub outputs: Vec<ValueInfo>,
    /// Ordered name -> tensor map (order is part of the serialized form).
    pub initializers: Vec<(String, Tensor)>,
}

impl Graph {
    pub fn initializer(&self, name: &str) -> Option<&Tensor> {
        self.initializers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| t)
    }

    pub fn input(&self, name: &str) -> Option<&ValueInfo> {
        self.inputs.iter().find(|v| v.name == name)
    }

    pub fn output(&self, name: &str) -> Option<&ValueInfo> {
        self.outputs.iter().find(|v| v.name == name)
    }

    /// Names of graph inputs that are NOT initializers (i.e. the runtime
    /// feeds). ONNX allows initializers to shadow inputs; we keep them
    /// disjoint but filter defensively.
    pub fn runtime_inputs(&self) -> Vec<&ValueInfo> {
        self.inputs
            .iter()
            .filter(|v| self.initializer(&v.name).is_none())
            .collect()
    }

    /// The node producing a given value name, if any.
    pub fn producer(&self, value: &str) -> Option<&Node> {
        self.nodes
            .iter()
            .find(|n| n.outputs.iter().any(|o| o == value))
    }
}

/// Top-level model: graph + versioning metadata (`ModelProto`).
#[derive(Clone, Debug, PartialEq)]
pub struct Model {
    pub ir_version: i64,
    pub opset_version: i64,
    pub producer_name: String,
    pub doc: String,
    pub graph: Graph,
    /// Free-form metadata props. The paper's goal 1 forbids *requiring*
    /// metadata for execution; we only store provenance here (never read
    /// by any backend).
    pub metadata: Vec<(String, String)>,
}

impl Model {
    pub fn new(graph: Graph) -> Model {
        Model {
            ir_version: 8,
            // Opset 13+: QuantizeLinear/DequantizeLinear with int8/uint8
            // zero-point dtype selection, MatMulInteger/ConvInteger (10+).
            opset_version: 13,
            producer_name: "pqdl".to_string(),
            doc: String::new(),
            graph,
            metadata: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_attrs() {
        let n = Node::new("n0", "Conv", &["x", "w"], &["y"])
            .with_attr("strides", Attr::Ints(vec![1, 1]))
            .with_attr("group", Attr::Int(1));
        assert_eq!(n.attr_int("group"), Some(1));
        assert_eq!(n.attr_ints("strides"), Some(&[1i64, 1][..]));
        assert!(n.attr("pads").is_none());
    }

    #[test]
    fn value_info_resolution() {
        let vi = ValueInfo::new(
            "x",
            DType::I8,
            &[Dim::Symbolic("N".into()), Dim::Fixed(64)],
        );
        assert_eq!(vi.fixed_shape(), None);
        let mut b = BTreeMap::new();
        b.insert("N".to_string(), 4usize);
        assert_eq!(vi.resolve_shape(&b), Some(vec![4, 64]));
    }

    #[test]
    fn graph_lookups() {
        let mut g = Graph {
            name: "g".into(),
            ..Default::default()
        };
        g.inputs.push(ValueInfo::fixed("x", DType::I8, &[1, 4]));
        g.initializers
            .push(("w".into(), Tensor::from_i8(&[4, 2], vec![0; 8]).unwrap()));
        g.nodes
            .push(Node::new("mm", "MatMulInteger", &["x", "w"], &["y"]));
        assert!(g.initializer("w").is_some());
        assert_eq!(g.runtime_inputs().len(), 1);
        assert_eq!(g.producer("y").unwrap().name, "mm");
        assert!(g.producer("z").is_none());
    }
}
