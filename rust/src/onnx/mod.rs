//! ONNX-compatible model representation.
//!
//! This is the paper's interchange substrate, built from scratch: the
//! ONNX object model ([`ir`]), a lossless JSON text serialization
//! ([`serde`], [`json`]), topological scheduling ([`topo`]), shape/dtype
//! inference ([`shape`]) and a validator ([`check`]) that — per the
//! paper's goal 3 — admits only standard operators.

pub mod build;
pub mod check;
pub mod ir;
pub mod json;
pub mod serde;
pub mod shape;
pub mod topo;

pub use build::{batched, fixed_dims, GraphBuilder};
pub use check::{check_model, CheckError, STANDARD_OPS};
pub use ir::{Attr, Dim, Graph, Model, Node, ValueInfo};
pub use serde::{load_model, model_from_json, model_to_json, save_model};
pub use shape::{infer_graph, ValueType};
pub use topo::topo_order;
