//! Model validation.
//!
//! Enforces the paper's goal 3 directly: only *standard* ONNX operators
//! are admitted (a custom op would make the model unusable in standard
//! tools), plus structural well-formedness: unique value names, all
//! consumed values defined, declared output types consistent with shape
//! inference, acyclicity.

use super::ir::{Dim, Model};
use super::shape::{infer_graph, ShapeError};
use std::collections::HashSet;
use thiserror::Error;

/// The standard ONNX operators this opset-13 subset admits. All of the
/// paper's Figure 1–6 patterns are expressible with exactly these.
pub const STANDARD_OPS: &[&str] = &[
    "Add",
    "AveragePool",
    "Cast",
    "Conv",
    "ConvInteger",
    "DequantizeLinear",
    "Div",
    "Flatten",
    "Gemm",
    "Identity",
    "MatMul",
    "MatMulInteger",
    "MaxPool",
    "Mul",
    "QuantizeLinear",
    "Relu",
    "Reshape",
    "Sigmoid",
    "Softmax",
    "Sub",
    "Tanh",
];

#[derive(Error, Debug)]
pub enum CheckError {
    #[error("non-standard operator '{op}' in node '{node}' (paper goal 3 forbids custom ops)")]
    NonStandardOp { op: String, node: String },
    #[error("duplicate node name '{0}'")]
    DuplicateNode(String),
    #[error("duplicate initializer '{0}'")]
    DuplicateInitializer(String),
    #[error("graph input '{0}' duplicated")]
    DuplicateInput(String),
    #[error("declared output '{name}' was never produced")]
    MissingOutput { name: String },
    #[error("declared output '{name}' has dtype {declared} but inference found {inferred}")]
    OutputDtypeMismatch {
        name: String,
        declared: String,
        inferred: String,
    },
    #[error("declared output '{name}' shape {declared:?} incompatible with inferred {inferred:?}")]
    OutputShapeMismatch {
        name: String,
        declared: Vec<Dim>,
        inferred: Vec<Dim>,
    },
    #[error(transparent)]
    Shape(#[from] ShapeError),
}

/// Validate a model. Returns the inferred value types on success so
/// callers (interpreter, hwsim, rewriter) can reuse them.
pub fn check_model(
    model: &Model,
) -> Result<std::collections::HashMap<String, super::shape::ValueType>, CheckError> {
    let g = &model.graph;

    // Standard-ops-only (goal 3).
    for n in &g.nodes {
        if !STANDARD_OPS.contains(&n.op_type.as_str()) {
            return Err(CheckError::NonStandardOp {
                op: n.op_type.clone(),
                node: n.name.clone(),
            });
        }
    }

    // Name uniqueness.
    let mut seen = HashSet::new();
    for n in &g.nodes {
        if !n.name.is_empty() && !seen.insert(n.name.as_str()) {
            return Err(CheckError::DuplicateNode(n.name.clone()));
        }
    }
    let mut seen = HashSet::new();
    for (name, _) in &g.initializers {
        if !seen.insert(name.as_str()) {
            return Err(CheckError::DuplicateInitializer(name.clone()));
        }
    }
    let mut seen = HashSet::new();
    for vi in &g.inputs {
        if !seen.insert(vi.name.as_str()) {
            return Err(CheckError::DuplicateInput(vi.name.clone()));
        }
    }

    // Full inference (includes topo/cycle/undefined-value checks).
    let types = infer_graph(g)?;

    // Declared outputs must match inference.
    for out in &g.outputs {
        let inferred = types
            .get(&out.name)
            .ok_or_else(|| CheckError::MissingOutput {
                name: out.name.clone(),
            })?;
        if inferred.dtype != out.dtype {
            return Err(CheckError::OutputDtypeMismatch {
                name: out.name.clone(),
                declared: out.dtype.to_string(),
                inferred: inferred.dtype.to_string(),
            });
        }
        if inferred.shape.len() != out.shape.len()
            || inferred
                .shape
                .iter()
                .zip(&out.shape)
                .any(|(a, b)| !dims_compatible(a, b))
        {
            return Err(CheckError::OutputShapeMismatch {
                name: out.name.clone(),
                declared: out.shape.clone(),
                inferred: inferred.shape.clone(),
            });
        }
    }
    Ok(types)
}

/// Declared vs inferred dim compatibility: symbolic matches anything with
/// the same symbol, and a declared symbolic dim accepts an inferred fixed
/// one (the author may declare looser).
fn dims_compatible(inferred: &Dim, declared: &Dim) -> bool {
    match (inferred, declared) {
        (Dim::Fixed(a), Dim::Fixed(b)) => a == b,
        (Dim::Symbolic(a), Dim::Symbolic(b)) => a == b,
        (Dim::Fixed(_), Dim::Symbolic(_)) => true,
        (Dim::Symbolic(_), Dim::Fixed(_)) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::ir::{Graph, Model, Node, ValueInfo};
    use crate::tensor::{DType, Tensor};

    fn ok_model() -> Model {
        let mut g = Graph {
            name: "m".into(),
            ..Default::default()
        };
        g.inputs.push(ValueInfo::fixed("x", DType::I8, &[1, 4]));
        g.initializers
            .push(("w".into(), Tensor::from_i8(&[4, 2], vec![0; 8]).unwrap()));
        g.nodes
            .push(Node::new("mm", "MatMulInteger", &["x", "w"], &["y"]));
        g.outputs.push(ValueInfo::fixed("y", DType::I32, &[1, 2]));
        Model::new(g)
    }

    #[test]
    fn accepts_valid() {
        assert!(check_model(&ok_model()).is_ok());
    }

    #[test]
    fn rejects_custom_op() {
        let mut m = ok_model();
        m.graph.nodes[0].op_type = "MyAcceleratorOp".into();
        assert!(matches!(
            check_model(&m),
            Err(CheckError::NonStandardOp { .. })
        ));
    }

    #[test]
    fn rejects_output_dtype_mismatch() {
        let mut m = ok_model();
        m.graph.outputs[0].dtype = DType::F32;
        assert!(matches!(
            check_model(&m),
            Err(CheckError::OutputDtypeMismatch { .. })
        ));
    }

    #[test]
    fn rejects_missing_output() {
        let mut m = ok_model();
        m.graph.outputs[0].name = "nope".into();
        assert!(matches!(
            check_model(&m),
            Err(CheckError::MissingOutput { .. })
        ));
    }

    #[test]
    fn rejects_duplicate_initializer() {
        let mut m = ok_model();
        m.graph
            .initializers
            .push(("w".into(), Tensor::from_i8(&[1], vec![0]).unwrap()));
        assert!(matches!(
            check_model(&m),
            Err(CheckError::DuplicateInitializer(_))
        ));
    }

    #[test]
    fn rejects_bad_output_shape() {
        let mut m = ok_model();
        m.graph.outputs[0] = ValueInfo::fixed("y", DType::I32, &[1, 3]);
        assert!(matches!(
            check_model(&m),
            Err(CheckError::OutputShapeMismatch { .. })
        ));
    }
}
