//! Model validation.
//!
//! Enforces the paper's goal 3 directly: only *standard* ONNX operators
//! are admitted (a custom op would make the model unusable in standard
//! tools), plus structural well-formedness: unique value names, all
//! consumed values defined, declared output types consistent with shape
//! inference, acyclicity.

use super::ir::{Dim, Model};
use super::shape::{infer_graph, ShapeError};
use std::collections::HashSet;
use thiserror::Error;

/// The standard ONNX operators this opset-13 subset admits. All of the
/// paper's Figure 1–6 patterns are expressible with exactly these.
pub const STANDARD_OPS: &[&str] = &[
    "Add",
    "AveragePool",
    "Cast",
    "Clip",
    "Conv",
    "ConvInteger",
    "DequantizeLinear",
    "Div",
    "Flatten",
    "Gemm",
    "Identity",
    "MatMul",
    "MatMulInteger",
    "MaxPool",
    "Mul",
    "QuantizeLinear",
    "Relu",
    "Reshape",
    "Sigmoid",
    "Softmax",
    "Sub",
    "Tanh",
];

#[derive(Error, Debug)]
pub enum CheckError {
    #[error("non-standard operator '{op}' in node '{node}' (paper goal 3 forbids custom ops)")]
    NonStandardOp { op: String, node: String },
    #[error("duplicate node name '{0}'")]
    DuplicateNode(String),
    #[error("duplicate initializer '{0}'")]
    DuplicateInitializer(String),
    #[error("graph input '{0}' duplicated")]
    DuplicateInput(String),
    #[error("declared output '{name}' was never produced")]
    MissingOutput { name: String },
    #[error("declared output '{name}' has dtype {declared} but inference found {inferred}")]
    OutputDtypeMismatch {
        name: String,
        declared: String,
        inferred: String,
    },
    #[error("declared output '{name}' shape {declared:?} incompatible with inferred {inferred:?}")]
    OutputShapeMismatch {
        name: String,
        declared: Vec<Dim>,
        inferred: Vec<Dim>,
    },
    #[error("width metadata '{key}': {reason}")]
    WidthMetadata { key: String, reason: String },
    #[error(transparent)]
    Shape(#[from] ShapeError),
}

/// Metadata-prop prefix declaring an initializer's *logical* weight
/// width (`pqdl.width.<initializer> = int4 | bipolar | ...`) — the
/// QONNX-style container-vs-logical split: the tensor is stored in a
/// standard 8-bit container, the annotation says how many of those bits
/// carry signal. Strictly advisory, honoring paper goal 1 (no metadata
/// is ever *required* for execution — the optimizer re-derives widths
/// from the weight values themselves), but when present the checker
/// verifies it, so a stale annotation fails fast instead of lying.
pub const WIDTH_META_PREFIX: &str = "pqdl.width.";

/// Validate a model. Returns the inferred value types on success so
/// callers (interpreter, hwsim, rewriter) can reuse them.
pub fn check_model(
    model: &Model,
) -> Result<std::collections::HashMap<String, super::shape::ValueType>, CheckError> {
    let g = &model.graph;

    // Standard-ops-only (goal 3).
    for n in &g.nodes {
        if !STANDARD_OPS.contains(&n.op_type.as_str()) {
            return Err(CheckError::NonStandardOp {
                op: n.op_type.clone(),
                node: n.name.clone(),
            });
        }
    }

    // Name uniqueness.
    let mut seen = HashSet::new();
    for n in &g.nodes {
        if !n.name.is_empty() && !seen.insert(n.name.as_str()) {
            return Err(CheckError::DuplicateNode(n.name.clone()));
        }
    }
    let mut seen = HashSet::new();
    for (name, _) in &g.initializers {
        if !seen.insert(name.as_str()) {
            return Err(CheckError::DuplicateInitializer(name.clone()));
        }
    }
    let mut seen = HashSet::new();
    for vi in &g.inputs {
        if !seen.insert(vi.name.as_str()) {
            return Err(CheckError::DuplicateInput(vi.name.clone()));
        }
    }

    // Advisory width metadata: never required, but when present it must
    // name a real initializer, parse as a known width, and admit the
    // stored values.
    for (key, val) in &model.metadata {
        let Some(init_name) = key.strip_prefix(WIDTH_META_PREFIX) else {
            continue;
        };
        let qt = crate::quant::QType::parse(val).ok_or_else(|| CheckError::WidthMetadata {
            key: key.clone(),
            reason: format!("unknown width '{val}'"),
        })?;
        let Some(t) = g.initializer(init_name) else {
            return Err(CheckError::WidthMetadata {
                key: key.clone(),
                reason: "no such initializer".into(),
            });
        };
        let vals = t
            .as_quantized_i32()
            .map_err(|_| CheckError::WidthMetadata {
                key: key.clone(),
                reason: format!("initializer is {}, not a quantized dtype", t.dtype()),
            })?;
        if !qt.admits(&vals) {
            return Err(CheckError::WidthMetadata {
                key: key.clone(),
                reason: format!("values exceed the declared {} range", qt.name()),
            });
        }
    }

    // Full inference (includes topo/cycle/undefined-value checks).
    let types = infer_graph(g)?;

    // Declared outputs must match inference.
    for out in &g.outputs {
        let inferred = types
            .get(&out.name)
            .ok_or_else(|| CheckError::MissingOutput {
                name: out.name.clone(),
            })?;
        if inferred.dtype != out.dtype {
            return Err(CheckError::OutputDtypeMismatch {
                name: out.name.clone(),
                declared: out.dtype.to_string(),
                inferred: inferred.dtype.to_string(),
            });
        }
        if inferred.shape.len() != out.shape.len()
            || inferred
                .shape
                .iter()
                .zip(&out.shape)
                .any(|(a, b)| !dims_compatible(a, b))
        {
            return Err(CheckError::OutputShapeMismatch {
                name: out.name.clone(),
                declared: out.shape.clone(),
                inferred: inferred.shape.clone(),
            });
        }
    }
    Ok(types)
}

/// Declared vs inferred dim compatibility: symbolic matches anything with
/// the same symbol, and a declared symbolic dim accepts an inferred fixed
/// one (the author may declare looser).
fn dims_compatible(inferred: &Dim, declared: &Dim) -> bool {
    match (inferred, declared) {
        (Dim::Fixed(a), Dim::Fixed(b)) => a == b,
        (Dim::Symbolic(a), Dim::Symbolic(b)) => a == b,
        (Dim::Fixed(_), Dim::Symbolic(_)) => true,
        (Dim::Symbolic(_), Dim::Fixed(_)) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::ir::{Graph, Model, Node, ValueInfo};
    use crate::tensor::{DType, Tensor};

    fn ok_model() -> Model {
        let mut g = Graph {
            name: "m".into(),
            ..Default::default()
        };
        g.inputs.push(ValueInfo::fixed("x", DType::I8, &[1, 4]));
        g.initializers
            .push(("w".into(), Tensor::from_i8(&[4, 2], vec![0; 8]).unwrap()));
        g.nodes
            .push(Node::new("mm", "MatMulInteger", &["x", "w"], &["y"]));
        g.outputs.push(ValueInfo::fixed("y", DType::I32, &[1, 2]));
        Model::new(g)
    }

    #[test]
    fn accepts_valid() {
        assert!(check_model(&ok_model()).is_ok());
    }

    #[test]
    fn rejects_custom_op() {
        let mut m = ok_model();
        m.graph.nodes[0].op_type = "MyAcceleratorOp".into();
        assert!(matches!(
            check_model(&m),
            Err(CheckError::NonStandardOp { .. })
        ));
    }

    #[test]
    fn rejects_output_dtype_mismatch() {
        let mut m = ok_model();
        m.graph.outputs[0].dtype = DType::F32;
        assert!(matches!(
            check_model(&m),
            Err(CheckError::OutputDtypeMismatch { .. })
        ));
    }

    #[test]
    fn rejects_missing_output() {
        let mut m = ok_model();
        m.graph.outputs[0].name = "nope".into();
        assert!(matches!(
            check_model(&m),
            Err(CheckError::MissingOutput { .. })
        ));
    }

    #[test]
    fn rejects_duplicate_initializer() {
        let mut m = ok_model();
        m.graph
            .initializers
            .push(("w".into(), Tensor::from_i8(&[1], vec![0]).unwrap()));
        assert!(matches!(
            check_model(&m),
            Err(CheckError::DuplicateInitializer(_))
        ));
    }

    #[test]
    fn width_metadata_is_advisory_but_verified() {
        // Valid annotation: the i8 container holds int4-range values.
        let mut m = ok_model();
        m.metadata
            .push(("pqdl.width.w".into(), "int4".into()));
        assert!(check_model(&m).is_ok());
        // Unknown width name.
        let mut m = ok_model();
        m.metadata
            .push(("pqdl.width.w".into(), "int12".into()));
        assert!(matches!(
            check_model(&m),
            Err(CheckError::WidthMetadata { .. })
        ));
        // Annotation naming a missing initializer.
        let mut m = ok_model();
        m.metadata
            .push(("pqdl.width.nope".into(), "int4".into()));
        assert!(matches!(
            check_model(&m),
            Err(CheckError::WidthMetadata { .. })
        ));
        // Values outside the declared range (zeros are not bipolar).
        let mut m = ok_model();
        m.metadata
            .push(("pqdl.width.w".into(), "bipolar".into()));
        assert!(matches!(
            check_model(&m),
            Err(CheckError::WidthMetadata { .. })
        ));
        // Unrelated metadata keys stay free-form.
        let mut m = ok_model();
        m.metadata.push(("author".into(), "whoever".into()));
        assert!(check_model(&m).is_ok());
    }

    #[test]
    fn rejects_bad_output_shape() {
        let mut m = ok_model();
        m.graph.outputs[0] = ValueInfo::fixed("y", DType::I32, &[1, 3]);
        assert!(matches!(
            check_model(&m),
            Err(CheckError::OutputShapeMismatch { .. })
        ));
    }
}
