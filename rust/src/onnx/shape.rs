//! Per-operator shape and dtype inference over the standard opset subset
//! used by the paper's patterns (plus the fp32 originals they are lowered
//! from). Batch dims may be symbolic (`Dim::Symbolic`); spatial and
//! feature dims must be fixed, matching how the paper's models are
//! authored (fixed layer sizes, free batch).

use super::ir::{Dim, Graph, Node};
use crate::tensor::DType;
use std::collections::HashMap;
use thiserror::Error;

/// Inferred type of one graph value.
#[derive(Clone, Debug, PartialEq)]
pub struct ValueType {
    pub dtype: DType,
    pub shape: Vec<Dim>,
}

impl ValueType {
    pub fn new(dtype: DType, shape: Vec<Dim>) -> ValueType {
        ValueType { dtype, shape }
    }

    pub fn fixed(dtype: DType, shape: &[usize]) -> ValueType {
        ValueType {
            dtype,
            shape: shape.iter().map(|&d| Dim::Fixed(d)).collect(),
        }
    }
}

#[derive(Error, Debug)]
pub enum ShapeError {
    #[error("node '{node}' ({op}): {msg}")]
    Infer {
        node: String,
        op: String,
        msg: String,
    },
    #[error("unsupported operator '{0}'")]
    UnsupportedOp(String),
    #[error("topology: {0}")]
    Topo(#[from] super::topo::TopoError),
}

fn err(node: &Node, msg: impl Into<String>) -> ShapeError {
    ShapeError::Infer {
        node: node.name.clone(),
        op: node.op_type.clone(),
        msg: msg.into(),
    }
}

fn dims_eq(a: &Dim, b: &Dim) -> bool {
    match (a, b) {
        (Dim::Fixed(x), Dim::Fixed(y)) => x == y,
        (Dim::Symbolic(x), Dim::Symbolic(y)) => x == y,
        _ => false,
    }
}

/// Multidirectional (NumPy) broadcast over possibly-symbolic dims.
fn broadcast_dims(node: &Node, a: &[Dim], b: &[Dim]) -> Result<Vec<Dim>, ShapeError> {
    let rank = a.len().max(b.len());
    let one = Dim::Fixed(1);
    let mut out = Vec::with_capacity(rank);
    for i in 0..rank {
        let da = if i < rank - a.len() { &one } else { &a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { &one } else { &b[i - (rank - b.len())] };
        let d = if dims_eq(da, db) {
            da.clone()
        } else if matches!(da, Dim::Fixed(1)) {
            db.clone()
        } else if matches!(db, Dim::Fixed(1)) {
            da.clone()
        } else {
            return Err(err(node, format!("cannot broadcast {a:?} with {b:?}")));
        };
        out.push(d);
    }
    Ok(out)
}

/// Spatial output size of a conv/pool window.
fn window_out(
    node: &Node,
    input: usize,
    kernel: usize,
    pad_begin: usize,
    pad_end: usize,
    stride: usize,
    dilation: usize,
) -> Result<usize, ShapeError> {
    let eff_k = dilation * (kernel - 1) + 1;
    let padded = input + pad_begin + pad_end;
    if padded < eff_k {
        return Err(err(
            node,
            format!("window {eff_k} larger than padded input {padded}"),
        ));
    }
    Ok((padded - eff_k) / stride + 1)
}

/// Read 2-D conv/pool attributes with ONNX defaults.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvAttrs {
    pub strides: [usize; 2],
    pub pads: [usize; 4], // top, left, bottom, right
    pub dilations: [usize; 2],
    pub group: usize,
}

impl ConvAttrs {
    pub fn from_node(node: &Node) -> ConvAttrs {
        let get2 = |key: &str| -> [usize; 2] {
            node.attr_ints(key)
                .map(|v| [v[0] as usize, v[1] as usize])
                .unwrap_or([1, 1])
        };
        let pads = node
            .attr_ints("pads")
            .map(|v| [v[0] as usize, v[1] as usize, v[2] as usize, v[3] as usize])
            .unwrap_or([0, 0, 0, 0]);
        ConvAttrs {
            strides: get2("strides"),
            pads,
            dilations: get2("dilations"),
            group: node.attr_int("group").unwrap_or(1) as usize,
        }
    }
}

fn fixed_dim(node: &Node, d: &Dim, what: &str) -> Result<usize, ShapeError> {
    d.fixed()
        .ok_or_else(|| err(node, format!("{what} must be a fixed dim, got {d:?}")))
}

fn conv_like_shape(
    node: &Node,
    x: &ValueType,
    w: &ValueType,
) -> Result<Vec<Dim>, ShapeError> {
    if x.shape.len() != 4 || w.shape.len() != 4 {
        return Err(err(node, "expects NCHW input and MCkk weight"));
    }
    let attrs = ConvAttrs::from_node(node);
    let c_in = fixed_dim(node, &x.shape[1], "C")?;
    let h = fixed_dim(node, &x.shape[2], "H")?;
    let wdt = fixed_dim(node, &x.shape[3], "W")?;
    let m = fixed_dim(node, &w.shape[0], "M")?;
    let wc = fixed_dim(node, &w.shape[1], "weight C")?;
    let kh = fixed_dim(node, &w.shape[2], "kH")?;
    let kw = fixed_dim(node, &w.shape[3], "kW")?;
    if wc * attrs.group != c_in {
        return Err(err(
            node,
            format!("channel mismatch: input C={c_in}, weight C={wc}, group={}", attrs.group),
        ));
    }
    let oh = window_out(node, h, kh, attrs.pads[0], attrs.pads[2], attrs.strides[0], attrs.dilations[0])?;
    let ow = window_out(node, wdt, kw, attrs.pads[1], attrs.pads[3], attrs.strides[1], attrs.dilations[1])?;
    Ok(vec![
        x.shape[0].clone(),
        Dim::Fixed(m),
        Dim::Fixed(oh),
        Dim::Fixed(ow),
    ])
}

fn pool_shape(node: &Node, x: &ValueType) -> Result<Vec<Dim>, ShapeError> {
    if x.shape.len() != 4 {
        return Err(err(node, "expects NCHW input"));
    }
    let kernel = node
        .attr_ints("kernel_shape")
        .ok_or_else(|| err(node, "missing kernel_shape"))?;
    let attrs = ConvAttrs::from_node(node);
    let h = fixed_dim(node, &x.shape[2], "H")?;
    let w = fixed_dim(node, &x.shape[3], "W")?;
    let oh = window_out(node, h, kernel[0] as usize, attrs.pads[0], attrs.pads[2], attrs.strides[0], 1)?;
    let ow = window_out(node, w, kernel[1] as usize, attrs.pads[1], attrs.pads[3], attrs.strides[1], 1)?;
    Ok(vec![
        x.shape[0].clone(),
        x.shape[1].clone(),
        Dim::Fixed(oh),
        Dim::Fixed(ow),
    ])
}

/// Infer the output [`ValueType`]s of one node given its input types.
/// `graph` is consulted for shape-carrying initializers (Reshape).
pub fn infer_node(
    node: &Node,
    graph: &Graph,
    inputs: &[Option<&ValueType>],
) -> Result<Vec<ValueType>, ShapeError> {
    let req = |i: usize| -> Result<&ValueType, ShapeError> {
        inputs
            .get(i)
            .copied()
            .flatten()
            .ok_or_else(|| err(node, format!("missing required input #{i}")))
    };

    let out = match node.op_type.as_str() {
        "MatMulInteger" => {
            let a = req(0)?;
            let b = req(1)?;
            if !a.dtype.is_quantized_int() || !b.dtype.is_quantized_int() {
                return Err(err(node, format!("requires int8/uint8 inputs, got {}/{}", a.dtype, b.dtype)));
            }
            vec![ValueType::new(DType::I32, matmul_shape(node, a, b)?)]
        }
        "MatMul" => {
            let a = req(0)?;
            let b = req(1)?;
            if a.dtype != b.dtype || !a.dtype.is_float() {
                return Err(err(node, "requires matching float inputs"));
            }
            vec![ValueType::new(a.dtype, matmul_shape(node, a, b)?)]
        }
        "Gemm" => {
            let a = req(0)?;
            let b = req(1)?;
            if a.shape.len() != 2 || b.shape.len() != 2 {
                return Err(err(node, "Gemm expects rank-2 inputs"));
            }
            let trans_a = node.attr_int("transA").unwrap_or(0) != 0;
            let trans_b = node.attr_int("transB").unwrap_or(0) != 0;
            let (m, ka) = if trans_a {
                (a.shape[1].clone(), a.shape[0].clone())
            } else {
                (a.shape[0].clone(), a.shape[1].clone())
            };
            let (kb, n) = if trans_b {
                (b.shape[1].clone(), b.shape[0].clone())
            } else {
                (b.shape[0].clone(), b.shape[1].clone())
            };
            if !dims_eq(&ka, &kb) {
                return Err(err(node, format!("K mismatch {ka:?} vs {kb:?}")));
            }
            vec![ValueType::new(a.dtype, vec![m, n])]
        }
        "ConvInteger" => {
            let x = req(0)?;
            let w = req(1)?;
            if !x.dtype.is_quantized_int() || !w.dtype.is_quantized_int() {
                return Err(err(node, "requires int8/uint8 inputs"));
            }
            vec![ValueType::new(DType::I32, conv_like_shape(node, x, w)?)]
        }
        "Conv" => {
            let x = req(0)?;
            let w = req(1)?;
            if x.dtype != DType::F32 || w.dtype != DType::F32 {
                return Err(err(node, "fp32 Conv requires FLOAT inputs"));
            }
            vec![ValueType::new(DType::F32, conv_like_shape(node, x, w)?)]
        }
        "Add" | "Mul" | "Sub" | "Div" => {
            let a = req(0)?;
            let b = req(1)?;
            if a.dtype != b.dtype {
                return Err(err(node, format!("dtype mismatch {} vs {}", a.dtype, b.dtype)));
            }
            vec![ValueType::new(a.dtype, broadcast_dims(node, &a.shape, &b.shape)?)]
        }
        "Cast" => {
            let x = req(0)?;
            let to = node
                .attr_str("to")
                .and_then(DType::from_onnx_name)
                .ok_or_else(|| err(node, "missing/unknown 'to' dtype attr"))?;
            vec![ValueType::new(to, x.shape.clone())]
        }
        "QuantizeLinear" => {
            let x = req(0)?;
            let scale = req(1)?;
            if x.dtype != DType::F32 {
                return Err(err(node, "input must be FLOAT"));
            }
            if scale.dtype != DType::F32 {
                return Err(err(node, "y_scale must be FLOAT"));
            }
            // Zero-point dtype selects the output dtype (paper §3.1);
            // default int8 when omitted (ONNX defaults to uint8, but every
            // pattern in the paper passes an explicit zero point).
            let out_dtype = inputs
                .get(2)
                .copied()
                .flatten()
                .map(|zp| zp.dtype)
                .unwrap_or(DType::U8);
            if !out_dtype.is_quantized_int() {
                return Err(err(node, "zero_point must be INT8 or UINT8"));
            }
            vec![ValueType::new(out_dtype, x.shape.clone())]
        }
        "DequantizeLinear" => {
            let x = req(0)?;
            if !x.dtype.is_quantized_int() && x.dtype != DType::I32 {
                return Err(err(node, "input must be INT8/UINT8/INT32"));
            }
            vec![ValueType::new(DType::F32, x.shape.clone())]
        }
        "Relu" => {
            let x = req(0)?;
            if !matches!(x.dtype, DType::F32 | DType::F16 | DType::I32 | DType::I8) {
                return Err(err(node, format!("unsupported dtype {}", x.dtype)));
            }
            vec![x.clone()]
        }
        "Clip" => {
            // Opset 13: optional scalar min/max inputs of the same dtype
            // as x. The executor supports f32 (the sub-8-bit codification
            // emits it there); inference only pins the type algebra.
            let x = req(0)?;
            if x.dtype != DType::F32 {
                return Err(err(node, format!("unsupported dtype {}", x.dtype)));
            }
            for i in [1, 2] {
                if let Some(b) = inputs.get(i).copied().flatten() {
                    if b.dtype != x.dtype {
                        return Err(err(
                            node,
                            format!("bound dtype {} != input {}", b.dtype, x.dtype),
                        ));
                    }
                }
            }
            vec![x.clone()]
        }
        "Tanh" | "Sigmoid" => {
            let x = req(0)?;
            if !x.dtype.is_float() {
                return Err(err(node, format!("requires float input, got {}", x.dtype)));
            }
            vec![x.clone()]
        }
        "Softmax" => {
            let x = req(0)?;
            if x.dtype != DType::F32 {
                return Err(err(node, "requires FLOAT input"));
            }
            vec![x.clone()]
        }
        "MaxPool" => {
            let x = req(0)?;
            vec![ValueType::new(x.dtype, pool_shape(node, x)?)]
        }
        "AveragePool" => {
            let x = req(0)?;
            if x.dtype != DType::F32 {
                return Err(err(node, "requires FLOAT input"));
            }
            vec![ValueType::new(x.dtype, pool_shape(node, x)?)]
        }
        "Reshape" => {
            let x = req(0)?;
            let shape_name = node
                .inputs
                .get(1)
                .ok_or_else(|| err(node, "missing shape input"))?;
            let shape_t = graph
                .initializer(shape_name)
                .ok_or_else(|| err(node, "shape input must be an initializer"))?;
            let spec = shape_t
                .as_i64()
                .map_err(|e| err(node, format!("shape tensor: {e}")))?;
            vec![ValueType::new(x.dtype, reshape_dims(node, &x.shape, spec)?)]
        }
        "Flatten" => {
            let x = req(0)?;
            let axis = node.attr_int("axis").unwrap_or(1) as usize;
            if axis > x.shape.len() {
                return Err(err(node, "axis out of range"));
            }
            let fold = |dims: &[Dim]| -> Result<Dim, ShapeError> {
                if dims.is_empty() {
                    return Ok(Dim::Fixed(1));
                }
                if dims.len() == 1 {
                    return Ok(dims[0].clone());
                }
                let mut p = 1usize;
                for d in dims {
                    p *= fixed_dim(node, d, "flattened dim")?;
                }
                Ok(Dim::Fixed(p))
            };
            vec![ValueType::new(
                x.dtype,
                vec![fold(&x.shape[..axis])?, fold(&x.shape[axis..])?],
            )]
        }
        "Identity" => vec![req(0)?.clone()],
        other => return Err(ShapeError::UnsupportedOp(other.to_string())),
    };
    Ok(out)
}

fn matmul_shape(node: &Node, a: &ValueType, b: &ValueType) -> Result<Vec<Dim>, ShapeError> {
    // Supports A rank >= 2 (leading batch dims) with rank-2 B — the form
    // every pattern in the paper uses (weights are rank-2 initializers).
    if b.shape.len() != 2 {
        return Err(err(node, "B must be rank-2"));
    }
    if a.shape.len() < 2 {
        return Err(err(node, "A must be rank >= 2"));
    }
    let k_a = &a.shape[a.shape.len() - 1];
    let k_b = &b.shape[0];
    if !dims_eq(k_a, k_b) {
        return Err(err(node, format!("K mismatch: {k_a:?} vs {k_b:?}")));
    }
    let mut out = a.shape[..a.shape.len() - 1].to_vec();
    out.push(b.shape[1].clone());
    Ok(out)
}

fn reshape_dims(node: &Node, input: &[Dim], spec: &[i64]) -> Result<Vec<Dim>, ShapeError> {
    // ONNX Reshape: 0 copies the input dim, -1 infers. Symbolic input dims
    // are supported only where copied via 0 or where the -1 inference does
    // not need them.
    let mut out: Vec<Dim> = Vec::with_capacity(spec.len());
    let mut infer_at: Option<usize> = None;
    for (i, &s) in spec.iter().enumerate() {
        match s {
            0 => {
                let d = input
                    .get(i)
                    .ok_or_else(|| err(node, "0-dim copies out of range"))?;
                out.push(d.clone());
            }
            -1 => {
                if infer_at.is_some() {
                    return Err(err(node, "multiple -1 dims"));
                }
                infer_at = Some(i);
                out.push(Dim::Fixed(0)); // placeholder
            }
            s if s > 0 => out.push(Dim::Fixed(s as usize)),
            _ => return Err(err(node, format!("bad reshape dim {s}"))),
        }
    }
    if let Some(at) = infer_at {
        // Total elements must be computable: all input dims fixed except
        // ones that are copied symbolically AND cancel out.
        let mut sym_in: Vec<&str> = Vec::new();
        let mut fixed_in = 1usize;
        for d in input {
            match d {
                Dim::Fixed(n) => fixed_in *= n,
                Dim::Symbolic(s) => sym_in.push(s),
            }
        }
        let mut sym_out: Vec<&str> = Vec::new();
        let mut fixed_out = 1usize;
        for (i, d) in out.iter().enumerate() {
            if i == at {
                continue;
            }
            match d {
                Dim::Fixed(n) => fixed_out *= n,
                Dim::Symbolic(s) => sym_out.push(s),
            }
        }
        sym_in.sort_unstable();
        sym_out.sort_unstable();
        if sym_in != sym_out {
            return Err(err(node, "cannot infer -1 with unmatched symbolic dims"));
        }
        if fixed_out == 0 || fixed_in % fixed_out != 0 {
            return Err(err(node, format!("cannot infer -1: {fixed_in} vs {fixed_out}")));
        }
        out[at] = Dim::Fixed(fixed_in / fixed_out);
    }
    Ok(out)
}

/// True when some node couples values ACROSS axis 0 — i.e. executing the
/// graph per-row along a leading batch axis would change results. Among
/// [`crate::onnx::check::STANDARD_OPS`] only `Softmax` normalizing over
/// axis 0 can do so; an un-inferable input type is treated as coupling
/// (conservative). Shared guard of the batch-parallel executors
/// ([`crate::interp`] and [`crate::hwsim`]) so the row-coupling rule lives
/// in exactly one place.
pub fn couples_rows_on_axis0(graph: &Graph, types: &HashMap<String, ValueType>) -> bool {
    for node in &graph.nodes {
        if node.op_type != "Softmax" {
            continue;
        }
        let Some(t) = node.inputs.first().and_then(|n| types.get(n.as_str())) else {
            return true;
        };
        let rank = t.shape.len() as i64;
        let axis = node.attr_int("axis").unwrap_or(-1);
        let norm = if axis < 0 { axis + rank } else { axis };
        if norm == 0 {
            return true;
        }
    }
    false
}

/// Infer types for every value in the graph. Returns a map from value
/// name to [`ValueType`]; declared graph outputs are cross-checked.
pub fn infer_graph(graph: &Graph) -> Result<HashMap<String, ValueType>, ShapeError> {
    let order = super::topo::topo_order(graph)?;
    let mut types: HashMap<String, ValueType> = HashMap::new();
    for vi in &graph.inputs {
        types.insert(vi.name.clone(), ValueType::new(vi.dtype, vi.shape.clone()));
    }
    for (name, t) in &graph.initializers {
        types.insert(name.clone(), ValueType::fixed(t.dtype(), t.shape()));
    }
    for idx in order {
        let node = &graph.nodes[idx];
        let in_types: Vec<Option<&ValueType>> = node
            .inputs
            .iter()
            .map(|n| if n.is_empty() { None } else { types.get(n.as_str()) })
            .collect();
        let outs = infer_node(node, graph, &in_types)?;
        if outs.len() != node.outputs.len() {
            return Err(err(node, "output arity mismatch"));
        }
        for (name, vt) in node.outputs.iter().zip(outs) {
            if !name.is_empty() {
                types.insert(name.clone(), vt);
            }
        }
    }
    Ok(types)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::ir::{Attr, Graph, Node, ValueInfo};
    use crate::tensor::Tensor;

    fn fc_graph() -> Graph {
        // x:i8[N,4] @ w:i8[4,2] -> i32 -> +bias -> cast f32
        let mut g = Graph {
            name: "fc".into(),
            ..Default::default()
        };
        g.inputs.push(ValueInfo::new(
            "x",
            DType::I8,
            &[Dim::Symbolic("N".into()), Dim::Fixed(4)],
        ));
        g.initializers
            .push(("w".into(), Tensor::from_i8(&[4, 2], vec![0; 8]).unwrap()));
        g.initializers
            .push(("b".into(), Tensor::from_i32(&[2], vec![0; 2]).unwrap()));
        g.nodes
            .push(Node::new("mm", "MatMulInteger", &["x", "w"], &["acc"]));
        g.nodes.push(Node::new("add", "Add", &["acc", "b"], &["acc_b"]));
        g.nodes.push(
            Node::new("cast", "Cast", &["acc_b"], &["f"])
                .with_attr("to", Attr::Str("FLOAT".into())),
        );
        g
    }

    #[test]
    fn fc_inference() {
        let types = infer_graph(&fc_graph()).unwrap();
        let acc = &types["acc"];
        assert_eq!(acc.dtype, DType::I32);
        assert_eq!(acc.shape, vec![Dim::Symbolic("N".into()), Dim::Fixed(2)]);
        assert_eq!(types["acc_b"].dtype, DType::I32);
        assert_eq!(types["f"].dtype, DType::F32);
    }

    #[test]
    fn matmul_k_mismatch() {
        let mut g = fc_graph();
        g.initializers[0] = ("w".into(), Tensor::from_i8(&[3, 2], vec![0; 6]).unwrap());
        assert!(infer_graph(&g).is_err());
    }

    #[test]
    fn matmul_integer_rejects_float() {
        let mut g = fc_graph();
        g.inputs[0] = ValueInfo::new("x", DType::F32, &[Dim::Fixed(1), Dim::Fixed(4)]);
        assert!(infer_graph(&g).is_err());
    }

    #[test]
    fn conv_shapes() {
        let mut g = Graph {
            name: "c".into(),
            ..Default::default()
        };
        g.inputs
            .push(ValueInfo::fixed("x", DType::I8, &[1, 3, 8, 8]));
        g.initializers.push((
            "w".into(),
            Tensor::from_i8(&[4, 3, 3, 3], vec![0; 108]).unwrap(),
        ));
        g.nodes.push(
            Node::new("conv", "ConvInteger", &["x", "w"], &["y"])
                .with_attr("pads", Attr::Ints(vec![1, 1, 1, 1]))
                .with_attr("strides", Attr::Ints(vec![2, 2])),
        );
        let types = infer_graph(&g).unwrap();
        assert_eq!(types["y"].dtype, DType::I32);
        assert_eq!(
            types["y"].shape,
            vec![Dim::Fixed(1), Dim::Fixed(4), Dim::Fixed(4), Dim::Fixed(4)]
        );
    }

    #[test]
    fn quantize_linear_zero_point_selects_dtype() {
        let mut g = Graph {
            name: "q".into(),
            ..Default::default()
        };
        g.inputs.push(ValueInfo::fixed("x", DType::F32, &[2, 2]));
        g.initializers
            .push(("s".into(), Tensor::scalar_f32(1.0)));
        g.initializers
            .push(("zp_u8".into(), Tensor::scalar_u8(0)));
        g.nodes.push(Node::new(
            "q",
            "QuantizeLinear",
            &["x", "s", "zp_u8"],
            &["y"],
        ));
        let types = infer_graph(&g).unwrap();
        assert_eq!(types["y"].dtype, DType::U8);
    }

    #[test]
    fn reshape_with_zero_and_minus_one() {
        let mut g = Graph {
            name: "r".into(),
            ..Default::default()
        };
        g.inputs.push(ValueInfo::new(
            "x",
            DType::F32,
            &[Dim::Symbolic("N".into()), Dim::Fixed(4), Dim::Fixed(4)],
        ));
        g.initializers.push((
            "shape".into(),
            Tensor::from_i64(&[2], vec![0, -1]).unwrap(),
        ));
        g.nodes
            .push(Node::new("r", "Reshape", &["x", "shape"], &["y"]));
        let types = infer_graph(&g).unwrap();
        assert_eq!(
            types["y"].shape,
            vec![Dim::Symbolic("N".into()), Dim::Fixed(16)]
        );
    }

    #[test]
    fn flatten_symbolic_batch() {
        let mut g = Graph {
            name: "f".into(),
            ..Default::default()
        };
        g.inputs.push(ValueInfo::new(
            "x",
            DType::F32,
            &[Dim::Symbolic("N".into()), Dim::Fixed(2), Dim::Fixed(3)],
        ));
        g.nodes
            .push(Node::new("f", "Flatten", &["x"], &["y"]).with_attr("axis", Attr::Int(1)));
        let types = infer_graph(&g).unwrap();
        assert_eq!(
            types["y"].shape,
            vec![Dim::Symbolic("N".into()), Dim::Fixed(6)]
        );
    }

    #[test]
    fn unsupported_op() {
        let mut g = Graph {
            name: "u".into(),
            ..Default::default()
        };
        g.inputs.push(ValueInfo::fixed("x", DType::F32, &[1]));
        g.nodes.push(Node::new("n", "Einsum", &["x"], &["y"]));
        assert!(matches!(
            infer_graph(&g),
            Err(ShapeError::UnsupportedOp(_))
        ));
    }
}
