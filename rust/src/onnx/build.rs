//! Fluent graph construction used by the rewriter, the examples and the
//! tests. Generates unique value/node names and keeps the initializer
//! table alongside the node list.

use super::ir::{Attr, Dim, Graph, Model, Node, ValueInfo};
use crate::tensor::{DType, Tensor};

/// Builder for a [`Graph`] with automatic name generation.
pub struct GraphBuilder {
    graph: Graph,
    counter: usize,
}

impl GraphBuilder {
    pub fn new(name: &str) -> GraphBuilder {
        GraphBuilder {
            graph: Graph {
                name: name.to_string(),
                ..Default::default()
            },
            counter: 0,
        }
    }

    /// Fresh unique value name with a readable prefix.
    pub fn fresh(&mut self, prefix: &str) -> String {
        let name = format!("{prefix}_{}", self.counter);
        self.counter += 1;
        name
    }

    /// Declare a runtime graph input.
    pub fn input(&mut self, name: &str, dtype: DType, dims: &[Dim]) -> String {
        self.graph.inputs.push(ValueInfo::new(name, dtype, dims));
        name.to_string()
    }

    /// Declare a graph output.
    pub fn output(&mut self, name: &str, dtype: DType, dims: &[Dim]) {
        self.graph.outputs.push(ValueInfo::new(name, dtype, dims));
    }

    /// Add a named initializer (weight / bias / quant parameter).
    pub fn init(&mut self, name: &str, t: Tensor) -> String {
        self.graph.initializers.push((name.to_string(), t));
        name.to_string()
    }

    /// Add an initializer with a generated name.
    pub fn init_fresh(&mut self, prefix: &str, t: Tensor) -> String {
        let name = self.fresh(prefix);
        self.init(&name, t)
    }

    /// Append a node; returns its (single) output name.
    pub fn node(
        &mut self,
        op: &str,
        inputs: &[&str],
        attrs: &[(&str, Attr)],
    ) -> String {
        let out = self.fresh(&format!("{}_out", op.to_lowercase()));
        self.node_named(op, inputs, &[&out], attrs);
        out
    }

    /// Append a node with explicit output names.
    pub fn node_named(
        &mut self,
        op: &str,
        inputs: &[&str],
        outputs: &[&str],
        attrs: &[(&str, Attr)],
    ) {
        let name = self.fresh(op);
        let mut node = Node::new(&name, op, inputs, outputs);
        for (k, v) in attrs {
            node = node.with_attr(k, v.clone());
        }
        self.graph.nodes.push(node);
    }

    pub fn finish(self) -> Graph {
        self.graph
    }

    pub fn finish_model(self) -> Model {
        Model::new(self.graph)
    }
}

/// Shorthand: `[N, d0, d1...]` with a symbolic leading batch axis.
pub fn batched(dims: &[usize]) -> Vec<Dim> {
    std::iter::once(Dim::Symbolic("N".to_string()))
        .chain(dims.iter().map(|&d| Dim::Fixed(d)))
        .collect()
}

/// Shorthand: all-fixed dims.
pub fn fixed_dims(dims: &[usize]) -> Vec<Dim> {
    dims.iter().map(|&d| Dim::Fixed(d)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::check::check_model;

    #[test]
    fn builds_valid_graph() {
        let mut b = GraphBuilder::new("g");
        b.input("x", DType::I8, &batched(&[4]));
        b.init("w", Tensor::from_i8(&[4, 2], vec![1; 8]).unwrap());
        let y = b.node("MatMulInteger", &["x", "w"], &[]);
        b.output(&y, DType::I32, &batched(&[2]));
        let m = b.finish_model();
        assert!(check_model(&m).is_ok());
        assert_eq!(m.graph.nodes.len(), 1);
    }

    #[test]
    fn fresh_names_unique() {
        let mut b = GraphBuilder::new("g");
        let a = b.fresh("v");
        let c = b.fresh("v");
        assert_ne!(a, c);
    }
}
