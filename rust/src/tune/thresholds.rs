//! The single home of the "is this worth parallelizing" thresholds.
//!
//! Before the tuning subsystem these lived as per-file magic constants
//! (`ops/matmul.rs`, `ops/conv.rs`, the interp batch split, the hwsim
//! sub-batch schedule). They are gathered here so (a) there is exactly
//! one place to read the parallelism policy, and (b) the tunable subset
//! (the GEMM thresholds, via [`super::GemmConfig`]) has an authoritative
//! default to be measured against. The per-file `pub const`s survive as
//! aliases of [`Thresholds::DEFAULT`] fields, so existing call sites and
//! tests keep compiling unchanged.

/// Every execution-layer parallelism threshold, in one struct.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Thresholds {
    /// Minimum `m*k*n` multiply-accumulates before a GEMM dispatches to
    /// the pool (dispatch + wake-up costs a few microseconds).
    /// Was `ops::matmul::GEMM_PAR_MIN_WORK`.
    pub gemm_par_min_work: usize,
    /// Minimum output rows per parallel GEMM chunk.
    /// Was `ops::matmul::GEMM_PAR_MIN_ROWS`.
    pub gemm_par_min_rows: usize,
    /// Minimum `batch * macs_per_image` before a convolution dispatches
    /// its batch images to the pool. Was `ops::conv::CONV_PAR_MIN_WORK`.
    pub conv_par_min_work: usize,
    /// Minimum leading-axis rows before `interp::Session::run` splits a
    /// batch across the pool. Was `interp::PAR_MIN_BATCH`.
    pub batch_par_min: usize,
    /// Fixed sub-batch height of the hwsim schedule. NOT tunable: it is
    /// a constant of the SIMULATED hardware schedule, deliberately
    /// machine-independent so cost reports are identical everywhere —
    /// it lives here only so every split threshold is defined in one
    /// place. Was `hwsim::HW_SPLIT_ROWS`.
    pub hw_split_rows: usize,
}

impl Thresholds {
    /// The historical hand-picked values. `PQDL_TUNE=off` (and every
    /// untuned path) reproduces exactly these — asserted by
    /// `tests/tuner.rs`.
    pub const DEFAULT: Thresholds = Thresholds {
        gemm_par_min_work: 32 * 1024,
        gemm_par_min_rows: 2,
        conv_par_min_work: 32 * 1024,
        batch_par_min: 4,
        hw_split_rows: 4,
    };
}

impl Default for Thresholds {
    fn default() -> Thresholds {
        Thresholds::DEFAULT
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_file_aliases_agree_with_the_struct() {
        // The unification contract: the old per-file constants are this
        // struct's fields, not independent copies.
        assert_eq!(
            crate::ops::matmul::GEMM_PAR_MIN_WORK,
            Thresholds::DEFAULT.gemm_par_min_work
        );
        assert_eq!(
            crate::ops::matmul::GEMM_PAR_MIN_ROWS,
            Thresholds::DEFAULT.gemm_par_min_rows
        );
        assert_eq!(
            crate::ops::conv::CONV_PAR_MIN_WORK,
            Thresholds::DEFAULT.conv_par_min_work
        );
        assert_eq!(crate::interp::PAR_MIN_BATCH, Thresholds::DEFAULT.batch_par_min);
        assert_eq!(crate::hwsim::HW_SPLIT_ROWS, Thresholds::DEFAULT.hw_split_rows);
        assert_eq!(
            crate::hwsim::HW_PAR_MIN_BATCH,
            Thresholds::DEFAULT.hw_split_rows + 1
        );
    }
}
