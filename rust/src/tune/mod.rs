//! Cost-model-driven auto-tuning (ROADMAP item: close the loop between
//! the hwsim cost model and the real machine).
//!
//! The compilation side of the paper's co-design split used to hardcode
//! every performance-critical constant: GEMM tiles (KC=256, NR=8), the
//! `worth_parallel` thresholds, replica counts, batch windows. This
//! module replaces hand-picked constants with measured decisions at two
//! timescales:
//!
//! * **Plan time** ([`tuner`]): the packed int8 GEMM kernels are
//!   parameterized over a small candidate space ([`GemmConfig`]: KC ∈
//!   {128, 256, 512}, NR ∈ {4, 8, 16}, parallel row-split thresholds).
//!   Candidates are ranked by the `hwsim::cost` model, the top few are
//!   timed on the real machine with the model's actual baked weight
//!   panels, and the winner is stamped into the `CompiledPlan`
//!   (extending the plan-time ISA stamping pattern). Results are cached
//!   ([`cache`]) keyed by (model digest, GEMM shapes, ISA, nthreads) so
//!   tuning is paid once per deployment.
//! * **Serving time** ([`controller`]): a feedback loop over the
//!   coordinator's live metrics adjusts per-lane replica counts and
//!   batch windows, with hysteresis and bounds so it converges instead
//!   of oscillating.
//!
//! Every candidate kernel configuration is bit-identical to the scalar
//! differential oracle — per-element accumulation order is ascending-k
//! under ANY blocking (see `ops::matmul`), so tuning can never change an
//! output bit (proptested in `tests/tuner.rs`).
//!
//! Knobs: `PQDL_TUNE=off|cached|full` ([`TuneMode`]), `PQDL_TUNE_CACHE`
//! (on-disk cache path; in-memory only when unset), `PQDL_TUNE_TOPK`
//! (measured candidates per shortlist, default 3).

pub mod cache;
pub mod controller;
pub mod thresholds;
pub mod tuner;

pub use cache::{model_digest, TuneCache, TuneCacheStats};
pub use controller::{Controller, ControllerConfig, Decision, LaneObservation};
pub use thresholds::Thresholds;
pub use tuner::{tune_gemms, GemmProblem, ProblemKind, TuneOutcome, TuneSource};

use crate::ops::matmul::{GEMM_KC, GEMM_NR};
use std::fmt;
use std::sync::OnceLock;

/// Tile + parallel-threshold configuration of the packed int8 GEMM
/// kernels — the plan-time tuner's search space. Carried by `PackedB` /
/// `PackedA` (set at pack time, read by the kernels at run time) and
/// stamped into every `CompiledPlan` alongside the ISA.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GemmConfig {
    /// k-block size of the packed-B microkernel sweep.
    pub kc: usize,
    /// Column-panel width (output columns per register tile). Affects
    /// the packed memory LAYOUT; the SIMD twins engage only at the
    /// 8-lane width and every other value runs the (bit-identical)
    /// scalar kernels.
    pub nr: usize,
    /// Minimum `m*k*n` before the packed GEMM dispatches to the pool.
    pub par_min_work: usize,
    /// Minimum output rows per parallel chunk.
    pub par_min_rows: usize,
}

impl GemmConfig {
    /// The hand-picked constants every release so far shipped with.
    /// `PQDL_TUNE=off` uses exactly this — asserted by `tests/tuner.rs`.
    pub const DEFAULT: GemmConfig = GemmConfig {
        kc: GEMM_KC,
        nr: GEMM_NR,
        par_min_work: Thresholds::DEFAULT.gemm_par_min_work,
        par_min_rows: Thresholds::DEFAULT.gemm_par_min_rows,
    };

    /// The full candidate space the tuner ranks: KC ∈ {128, 256, 512} ×
    /// NR ∈ {4, 8, 16} × par_min_work ∈ {16 Ki, 32 Ki}. Small by design —
    /// the cost-model seed cuts it to a shortlist before anything is
    /// timed, so plan-time tuning stays bounded.
    pub fn candidates() -> Vec<GemmConfig> {
        let mut v = Vec::with_capacity(18);
        for &kc in &[128usize, 256, 512] {
            for &nr in &[4usize, 8, 16] {
                for &par_min_work in &[16 * 1024usize, 32 * 1024] {
                    v.push(GemmConfig {
                        kc,
                        nr,
                        par_min_work,
                        par_min_rows: Thresholds::DEFAULT.gemm_par_min_rows,
                    });
                }
            }
        }
        v
    }

    pub fn is_default(&self) -> bool {
        *self == GemmConfig::DEFAULT
    }
}

impl Default for GemmConfig {
    fn default() -> GemmConfig {
        GemmConfig::DEFAULT
    }
}

impl fmt::Display for GemmConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "kc{} nr{} parw{} parr{}",
            self.kc, self.nr, self.par_min_work, self.par_min_rows
        )
    }
}

/// The `PQDL_TUNE` knob: how much work plan compilation may spend on
/// tuning.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuneMode {
    /// No tuning, no cache: today's hand-picked constants, exactly.
    Off,
    /// Use a cached winner when one exists for (digest, shapes, ISA,
    /// nthreads); NEVER measure. The default: a warmed deployment gets
    /// its tuned plan for free, a cold one behaves like `off`.
    Cached,
    /// Cache hit, else measure the shortlist and store the winner.
    Full,
}

impl TuneMode {
    pub fn name(&self) -> &'static str {
        match self {
            TuneMode::Off => "off",
            TuneMode::Cached => "cached",
            TuneMode::Full => "full",
        }
    }

    /// Parse a knob value; unknown strings are `None` (callers fall back
    /// to the default mode rather than failing — same contract as
    /// `PQDL_FORCE_ISA`).
    pub fn from_name(s: &str) -> Option<TuneMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" => Some(TuneMode::Off),
            "cached" => Some(TuneMode::Cached),
            "full" => Some(TuneMode::Full),
            _ => None,
        }
    }

    /// The process-wide mode: `PQDL_TUNE` if set (unknown values fall
    /// back to `cached`), else `cached`. Decided once (`OnceLock`) so
    /// plan compilation never re-reads the environment — the same
    /// warm-once pattern as `Isa::active`.
    pub fn active() -> TuneMode {
        static ACTIVE: OnceLock<TuneMode> = OnceLock::new();
        *ACTIVE.get_or_init(|| {
            std::env::var("PQDL_TUNE")
                .ok()
                .and_then(|v| TuneMode::from_name(&v))
                .unwrap_or(TuneMode::Cached)
        })
    }
}

impl fmt::Display for TuneMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_the_historical_constants() {
        let d = GemmConfig::DEFAULT;
        assert_eq!(d.kc, 256);
        assert_eq!(d.nr, 8);
        assert_eq!(d.par_min_work, 32 * 1024);
        assert_eq!(d.par_min_rows, 2);
        assert!(d.is_default());
    }

    #[test]
    fn candidate_space_covers_the_issue_spec() {
        let c = GemmConfig::candidates();
        assert_eq!(c.len(), 18);
        // The default must be in the space (so "tuned" can mean "keep").
        assert!(c.contains(&GemmConfig::DEFAULT));
        for cfg in &c {
            assert!([128, 256, 512].contains(&cfg.kc));
            assert!([4, 8, 16].contains(&cfg.nr));
            assert!(cfg.nr <= crate::ops::matmul::GEMM_NR_MAX);
        }
    }

    #[test]
    fn mode_names_round_trip() {
        for m in [TuneMode::Off, TuneMode::Cached, TuneMode::Full] {
            assert_eq!(TuneMode::from_name(m.name()), Some(m));
        }
        assert_eq!(TuneMode::from_name(" FULL "), Some(TuneMode::Full));
        assert_eq!(TuneMode::from_name("bogus"), None);
    }
}
