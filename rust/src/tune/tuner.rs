//! Plan-time micro-tuner for the packed int8 GEMM kernels.
//!
//! The tuner answers one question at `Session::new` time: which
//! [`GemmConfig`] should this plan's packed kernels run with on THIS
//! machine? The pipeline is cost-seeded measurement:
//!
//! 1. collect the plan's GEMM problems — the actual baked weight
//!    matrices behind `MatMulIntegerPrebound` / `FusedQFc` (packed-B
//!    side) and `ConvIntegerPrebound` / `FusedQConv` (packed-A side);
//! 2. rank the full candidate space with the `hwsim::cost` model
//!    ([`crate::hwsim::cost::gemm_tile_estimate`]) — cheap, no timing;
//! 3. time only the top `PQDL_TUNE_TOPK` (default 3, plus the baseline
//!    default config) on the real machine: each candidate repacks the
//!    real weights and runs the real dispatch path against deterministic
//!    probe activations, best-of-3 wall time;
//! 4. the lowest total time wins and is stored in the [`super::cache`].
//!
//! Correctness never depends on the choice: every candidate visits k in
//! ascending order per output element (see `ops::matmul`), so all 18
//! configs produce bit-identical outputs — proptested in
//! `tests/tuner.rs`. Tuning can only move time, never bits.

use super::cache::{self, TuneCache};
use super::{GemmConfig, TuneMode};
use crate::ops::bitpack::{
    gemm_i2_packed_a_isa, gemm_i2_packed_par_isa, gemm_i3_packed_a_isa, gemm_i3_packed_par_isa,
    gemm_i4_packed_a_isa, gemm_i4_packed_par_isa, gemm_xnor_a_isa, gemm_xnor_par_isa,
    pack_bits_cols, pack_bits_rows, BitPackedA, BitPackedB, PackedA2, PackedA3, PackedA4,
    PackedB2, PackedB3, PackedB4,
};
use crate::ops::matmul::{
    gemm_i8_packed_a_isa, gemm_i8_packed_par_isa, PackedA, PackedB, GEMM_MR,
};
use crate::ops::Isa;
use crate::parallel::ThreadPool;
use std::sync::OnceLock;
use std::time::Instant;

/// Probe batch height (packed-B GEMMs) / im2col column count (packed-A
/// GEMMs) used for candidate timing: big enough to engage the parallel
/// split candidates, small enough that an 18-candidate shortlist sweep
/// stays in the low milliseconds for figure-scale models.
pub const TUNE_PROBE_ROWS: usize = 64;
/// Timed repetitions per candidate; the minimum is kept (standard
/// best-of-N to reject scheduler noise).
pub const TUNE_PROBE_REPS: usize = 3;

/// Which side of the GEMM the plan pre-packed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProblemKind {
    /// Weights are the B operand (`[k, out]`), activations stream as A —
    /// the FC / MatMulInteger shape.
    PackedBGemm,
    /// Weights are the A operand (`[out, k]`), im2col patches stream as
    /// B — the conv shape.
    PackedAGemm,
}

/// One GEMM a compiled plan will run in steady state: the real widened
/// weight matrix plus its shape. Borrowed from the kernel that owns it —
/// tuning measures the exact panels serving will use.
#[derive(Clone, Copy, Debug)]
pub struct GemmProblem<'a> {
    /// Widened (zero-point-folded) weights; layout per `kind`.
    pub w: &'a [i32],
    /// Reduction length.
    pub k: usize,
    /// Output features (B columns or A rows).
    pub out: usize,
    pub kind: ProblemKind,
    /// Logical weight bits of the packed storage this plan baked (8 / 4 /
    /// 3 / 2 / 1 — `PackedWeights::bits`): selects the kernel family the
    /// tuner times, and keys the cache so an int4 plan never reuses an
    /// int8 winner for the same shape.
    pub bits: u8,
}

impl GemmProblem<'_> {
    /// Cache-key shape token, e.g. `b64x32` / `a27x8`; narrow widths get
    /// a suffix (`b64x32w4`) so pre-existing int8 cache entries stay
    /// valid.
    fn shape_token(&self) -> String {
        let tag = match self.kind {
            ProblemKind::PackedBGemm => 'b',
            ProblemKind::PackedAGemm => 'a',
        };
        if self.bits == 8 {
            format!("{tag}{}x{}", self.k, self.out)
        } else {
            format!("{tag}{}x{}w{}", self.k, self.out, self.bits)
        }
    }
}

/// Where a plan's tuned config came from (surfaced via `plan_stats()`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuneSource {
    /// No tuning ran (mode off, cache miss in `cached` mode, or nothing
    /// to tune): the historical constants.
    Default,
    /// A prior measurement for the same (digest, shapes, ISA, nthreads).
    CacheHit,
    /// Measured in this process.
    Measured,
}

impl TuneSource {
    pub fn name(&self) -> &'static str {
        match self {
            TuneSource::Default => "default",
            TuneSource::CacheHit => "cache-hit",
            TuneSource::Measured => "measured",
        }
    }
}

/// The tuner's verdict for one plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TuneOutcome {
    pub cfg: GemmConfig,
    pub source: TuneSource,
}

impl TuneOutcome {
    pub const DEFAULT: TuneOutcome = TuneOutcome {
        cfg: GemmConfig::DEFAULT,
        source: TuneSource::Default,
    };
}

/// Sorted shape tokens for the cache key — sorted so kernel iteration
/// order (which follows plan step order) cannot perturb the key.
pub fn shape_key(problems: &[GemmProblem]) -> Vec<String> {
    let mut v: Vec<String> = problems.iter().map(|p| p.shape_token()).collect();
    v.sort();
    v
}

fn topk() -> usize {
    static TOPK: OnceLock<usize> = OnceLock::new();
    *TOPK.get_or_init(|| {
        std::env::var("PQDL_TUNE_TOPK")
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .filter(|&v| v > 0)
            .unwrap_or(3)
    })
}

/// Tune against the process-global cache (the `Session::new` path).
pub fn tune_gemms(
    digest: u64,
    problems: &[GemmProblem],
    isa: Isa,
    nthreads: usize,
    mode: TuneMode,
) -> TuneOutcome {
    tune_gemms_with(TuneCache::global(), digest, problems, isa, nthreads, mode)
}

/// Tune against an explicit cache (tests construct their own so they
/// never race on the global store or the environment).
pub fn tune_gemms_with(
    cache: &TuneCache,
    digest: u64,
    problems: &[GemmProblem],
    isa: Isa,
    nthreads: usize,
    mode: TuneMode,
) -> TuneOutcome {
    if mode == TuneMode::Off || problems.is_empty() {
        return TuneOutcome::DEFAULT;
    }
    let key = cache::key_line(digest, &shape_key(problems), isa, nthreads);
    if let Some(cfg) = cache.lookup(&key) {
        return TuneOutcome {
            cfg,
            source: TuneSource::CacheHit,
        };
    }
    if mode == TuneMode::Cached {
        return TuneOutcome::DEFAULT;
    }
    // Full mode, cache miss: measure, remember, count (the CI cache-hit
    // smoke asserts this counter stays flat on the second compile).
    cache::count_measurement();
    let cfg = measure_best(problems, isa).unwrap_or(GemmConfig::DEFAULT);
    cache.store(&key, cfg);
    TuneOutcome {
        cfg,
        source: TuneSource::Measured,
    }
}

/// Cost-model seed for one candidate over the whole problem set: ranks
/// without timing anything, so only a shortlist is ever measured.
fn seed_cost(cfg: &GemmConfig, problems: &[GemmProblem]) -> u64 {
    problems
        .iter()
        .map(|p| {
            let (m, n) = match p.kind {
                ProblemKind::PackedBGemm => (TUNE_PROBE_ROWS, p.out),
                ProblemKind::PackedAGemm => (p.out, TUNE_PROBE_ROWS),
            };
            crate::hwsim::cost::gemm_tile_estimate(GEMM_MR, cfg.nr, cfg.kc, m, p.k, n)
        })
        .sum()
}

/// Deterministic i8 probe activations (LCG; tuning must not depend on a
/// random source, or the winner would be irreproducible).
fn probe_i8(len: usize, seed: u64) -> Vec<i8> {
    let mut s = seed | 1;
    (0..len)
        .map(|_| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 33) & 0xff) as u8 as i8
        })
        .collect()
}

/// Deterministic ±1 probe activations for the XNOR problems (sign of the
/// i8 probe stream).
fn probe_pm1(len: usize, seed: u64) -> Vec<i8> {
    probe_i8(len, seed)
        .into_iter()
        .map(|v| if v >= 0 { 1 } else { -1 })
        .collect()
}

/// Best-of-[`TUNE_PROBE_REPS`] wall time of one candidate over every
/// problem, through the exact dispatch path serving uses — per width:
/// the i8 panel kernels, the int4 nibble kernels (whose layout follows
/// the candidate config), or the XNOR kernels (config-independent, so
/// they add the same constant to every candidate). `None` when a
/// problem's weights refuse to pack at the declared width — the caller
/// keeps the default config, same as the plan compiler would.
fn measure_candidate(cfg: GemmConfig, problems: &[GemmProblem], isa: Isa) -> Option<u64> {
    let pool = ThreadPool::global();
    let mut total = 0u64;
    for (idx, p) in problems.iter().enumerate() {
        let seed = 0x9e37_79b9_7f4a_7c15 ^ (idx as u64);
        let mut best = u64::MAX;
        macro_rules! time_reps {
            ($run:expr) => {{
                // One untimed warmup rep per problem (page faults, branch
                // history), then timed reps.
                $run;
                for _ in 0..TUNE_PROBE_REPS {
                    let t = Instant::now();
                    $run;
                    best = best.min(t.elapsed().as_nanos() as u64);
                }
            }};
        }
        match (p.kind, p.bits) {
            (ProblemKind::PackedBGemm, 4) => {
                let bp = PackedB4::pack_with(p.w, p.k, p.out, cfg)?;
                let a = probe_i8(TUNE_PROBE_ROWS * p.k, seed);
                let mut c = vec![0i32; TUNE_PROBE_ROWS * p.out];
                time_reps!(gemm_i4_packed_par_isa(pool, isa, &a, &bp, TUNE_PROBE_ROWS, &mut c));
            }
            (ProblemKind::PackedBGemm, 3) => {
                let bp = PackedB3::pack_with(p.w, p.k, p.out, cfg)?;
                let a = probe_i8(TUNE_PROBE_ROWS * p.k, seed);
                let mut c = vec![0i32; TUNE_PROBE_ROWS * p.out];
                time_reps!(gemm_i3_packed_par_isa(pool, isa, &a, &bp, TUNE_PROBE_ROWS, &mut c));
            }
            (ProblemKind::PackedBGemm, 2) => {
                let bp = PackedB2::pack_with(p.w, p.k, p.out, cfg)?;
                let a = probe_i8(TUNE_PROBE_ROWS * p.k, seed);
                let mut c = vec![0i32; TUNE_PROBE_ROWS * p.out];
                time_reps!(gemm_i2_packed_par_isa(pool, isa, &a, &bp, TUNE_PROBE_ROWS, &mut c));
            }
            (ProblemKind::PackedBGemm, 1) => {
                let bb = BitPackedB::pack(p.w, p.k, p.out)?;
                let a = probe_pm1(TUNE_PROBE_ROWS * p.k, seed);
                let mut a_bits = Vec::new();
                if !pack_bits_rows(&a, TUNE_PROBE_ROWS, p.k, &mut a_bits) {
                    return None;
                }
                let mut c = vec![0i32; TUNE_PROBE_ROWS * p.out];
                time_reps!(gemm_xnor_par_isa(pool, isa, &a_bits, &bb, TUNE_PROBE_ROWS, &mut c));
            }
            (ProblemKind::PackedBGemm, _) => {
                let bp = PackedB::pack_with(p.w, p.k, p.out, cfg)?;
                let a = probe_i8(TUNE_PROBE_ROWS * p.k, seed);
                let mut c = vec![0i32; TUNE_PROBE_ROWS * p.out];
                time_reps!(gemm_i8_packed_par_isa(pool, isa, &a, &bp, TUNE_PROBE_ROWS, &mut c));
            }
            (ProblemKind::PackedAGemm, 4) => {
                let ap = PackedA4::pack_with(p.w, p.out, p.k, cfg)?;
                let b = probe_i8(p.k * TUNE_PROBE_ROWS, seed);
                let mut c = vec![0i32; p.out * TUNE_PROBE_ROWS];
                time_reps!(gemm_i4_packed_a_isa(isa, &ap, &b, TUNE_PROBE_ROWS, &mut c));
            }
            (ProblemKind::PackedAGemm, 3) => {
                let ap = PackedA3::pack_with(p.w, p.out, p.k, cfg)?;
                let b = probe_i8(p.k * TUNE_PROBE_ROWS, seed);
                let mut c = vec![0i32; p.out * TUNE_PROBE_ROWS];
                time_reps!(gemm_i3_packed_a_isa(isa, &ap, &b, TUNE_PROBE_ROWS, &mut c));
            }
            (ProblemKind::PackedAGemm, 2) => {
                let ap = PackedA2::pack_with(p.w, p.out, p.k, cfg)?;
                let b = probe_i8(p.k * TUNE_PROBE_ROWS, seed);
                let mut c = vec![0i32; p.out * TUNE_PROBE_ROWS];
                time_reps!(gemm_i2_packed_a_isa(isa, &ap, &b, TUNE_PROBE_ROWS, &mut c));
            }
            (ProblemKind::PackedAGemm, 1) => {
                let ap = BitPackedA::pack(p.w, p.out, p.k)?;
                let b = probe_pm1(p.k * TUNE_PROBE_ROWS, seed);
                let mut b_bits = Vec::new();
                if !pack_bits_cols(&b, p.k, TUNE_PROBE_ROWS, &mut b_bits) {
                    return None;
                }
                let mut c = vec![0i32; p.out * TUNE_PROBE_ROWS];
                time_reps!(gemm_xnor_a_isa(isa, &ap, &b_bits, TUNE_PROBE_ROWS, &mut c));
            }
            (ProblemKind::PackedAGemm, _) => {
                let ap = PackedA::pack_with(p.w, p.out, p.k, cfg)?;
                let b = probe_i8(p.k * TUNE_PROBE_ROWS, seed);
                let mut c = vec![0i32; p.out * TUNE_PROBE_ROWS];
                time_reps!(gemm_i8_packed_a_isa(isa, &ap, &b, TUNE_PROBE_ROWS, &mut c));
            }
        }
        total = total.saturating_add(best);
    }
    Some(total)
}

/// Rank the candidate space by cost model, time the shortlist (top
/// `PQDL_TUNE_TOPK` + the default), return the fastest.
fn measure_best(problems: &[GemmProblem], isa: Isa) -> Option<GemmConfig> {
    let mut ranked: Vec<(u64, GemmConfig)> = GemmConfig::candidates()
        .into_iter()
        .map(|c| (seed_cost(&c, problems), c))
        .collect();
    ranked.sort_by_key(|&(s, _)| s);
    let mut shortlist: Vec<GemmConfig> =
        ranked.iter().take(topk()).map(|&(_, c)| c).collect();
    // The incumbent always competes: "tuned" may legitimately mean
    // "keep the hand-picked constants".
    if !shortlist.contains(&GemmConfig::DEFAULT) {
        shortlist.push(GemmConfig::DEFAULT);
    }
    let mut best: Option<(u64, GemmConfig)> = None;
    for cfg in shortlist {
        let ns = measure_candidate(cfg, problems, isa)?;
        if best.map_or(true, |(b, _)| ns < b) {
            best = Some((ns, cfg));
        }
    }
    best.map(|(_, c)| c)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_problems() -> (Vec<i32>, Vec<i32>) {
        let bw: Vec<i32> = (0..12 * 10).map(|i| ((i * 7) % 31) - 15).collect();
        let aw: Vec<i32> = (0..6 * 9).map(|i| ((i * 5) % 23) - 11).collect();
        (bw, aw)
    }

    #[test]
    fn shape_key_is_order_independent() {
        let (bw, aw) = toy_problems();
        let p1 = GemmProblem { w: &bw, k: 12, out: 10, kind: ProblemKind::PackedBGemm, bits: 8 };
        let p2 = GemmProblem { w: &aw, k: 9, out: 6, kind: ProblemKind::PackedAGemm, bits: 8 };
        assert_eq!(shape_key(&[p1, p2]), shape_key(&[p2, p1]));
        assert_eq!(shape_key(&[p1, p2]), vec!["a9x6".to_string(), "b12x10".to_string()]);
    }

    #[test]
    fn off_and_empty_return_default_without_touching_the_cache() {
        let cache = TuneCache::new(None);
        let (bw, _) = toy_problems();
        let p = GemmProblem { w: &bw, k: 12, out: 10, kind: ProblemKind::PackedBGemm, bits: 8 };
        let out = tune_gemms_with(&cache, 1, &[p], Isa::Scalar, 1, TuneMode::Off);
        assert_eq!(out, TuneOutcome::DEFAULT);
        let out = tune_gemms_with(&cache, 1, &[], Isa::Scalar, 1, TuneMode::Full);
        assert_eq!(out, TuneOutcome::DEFAULT);
        assert!(cache.is_empty());
    }

    #[test]
    fn cached_mode_never_measures_and_full_mode_populates() {
        let cache = TuneCache::new(None);
        let (bw, aw) = toy_problems();
        let ps = [
            GemmProblem { w: &bw, k: 12, out: 10, kind: ProblemKind::PackedBGemm, bits: 8 },
            GemmProblem { w: &aw, k: 9, out: 6, kind: ProblemKind::PackedAGemm, bits: 8 },
        ];
        // Cold cache in `cached` mode: default, nothing stored.
        let out = tune_gemms_with(&cache, 42, &ps, Isa::Scalar, 2, TuneMode::Cached);
        assert_eq!(out.source, TuneSource::Default);
        assert!(cache.is_empty());
        // `full` measures and stores a winner from the candidate space.
        let out = tune_gemms_with(&cache, 42, &ps, Isa::Scalar, 2, TuneMode::Full);
        assert_eq!(out.source, TuneSource::Measured);
        assert!(GemmConfig::candidates().contains(&out.cfg));
        assert_eq!(cache.len(), 1);
        // Same key now hits — in `cached` AND `full` mode.
        for mode in [TuneMode::Cached, TuneMode::Full] {
            let hit = tune_gemms_with(&cache, 42, &ps, Isa::Scalar, 2, mode);
            assert_eq!(hit.source, TuneSource::CacheHit);
            assert_eq!(hit.cfg, out.cfg);
        }
        // Perturb any key component: miss again.
        let miss = tune_gemms_with(&cache, 43, &ps, Isa::Scalar, 2, TuneMode::Cached);
        assert_eq!(miss.source, TuneSource::Default);
        let miss = tune_gemms_with(&cache, 42, &ps, Isa::Scalar, 3, TuneMode::Cached);
        assert_eq!(miss.source, TuneSource::Default);
    }

    #[test]
    fn narrow_widths_key_and_measure_through_their_kernels() {
        // Width is part of the cache key: an int4 plan must never reuse
        // an int8 winner for the same shape (different kernel family).
        let b4: Vec<i32> = (0..16 * 6).map(|i| (i as i32 % 16) - 8).collect();
        let b1: Vec<i32> = (0..6 * 16).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        let p8 = GemmProblem { w: &b4, k: 16, out: 6, kind: ProblemKind::PackedBGemm, bits: 8 };
        let p4 = GemmProblem { w: &b4, k: 16, out: 6, kind: ProblemKind::PackedBGemm, bits: 4 };
        let p1 = GemmProblem { w: &b1, k: 16, out: 6, kind: ProblemKind::PackedAGemm, bits: 1 };
        assert_eq!(shape_key(&[p8]), vec!["b16x6".to_string()]);
        assert_eq!(
            shape_key(&[p4, p1]),
            vec!["a16x6w1".to_string(), "b16x6w4".to_string()]
        );
        // Full mode measures the narrow kernel families end to end.
        let cache = TuneCache::new(None);
        let out = tune_gemms_with(&cache, 9, &[p4, p1], Isa::Scalar, 1, TuneMode::Full);
        assert_eq!(out.source, TuneSource::Measured);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn unpackable_weights_fall_back_to_default_config() {
        let cache = TuneCache::new(None);
        let bw = vec![1000i32; 8 * 8]; // out of i8 range: pack refuses
        let p = GemmProblem { w: &bw, k: 8, out: 8, kind: ProblemKind::PackedBGemm, bits: 8 };
        let out = tune_gemms_with(&cache, 7, &[p], Isa::Scalar, 1, TuneMode::Full);
        assert_eq!(out.cfg, GemmConfig::DEFAULT);
        assert_eq!(out.source, TuneSource::Measured);
        // The fallback is remembered too — no repeated futile measuring.
        let hit = tune_gemms_with(&cache, 7, &[p], Isa::Scalar, 1, TuneMode::Full);
        assert_eq!(hit.source, TuneSource::CacheHit);
    }
}
