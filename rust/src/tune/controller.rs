//! Serving-time feedback controller: adjusts per-lane replica counts and
//! batch windows from the live metrics the coordinator already records.
//!
//! Pure decision logic — no threads, no clocks, no atomics. The
//! coordinator ticks it with per-interval [`LaneObservation`] deltas
//! (diffed from the cumulative `Metrics` snapshots) and applies the
//! returned [`Decision`]; that split keeps the policy property-testable
//! with synthetic traces (`tests/tuner.rs`).
//!
//! Convergence is by construction, not tuning luck:
//!
//! * **Deadband**: the scale-up condition (backlog) and the scale-down
//!   condition (light) are separated by a gap — queue time must exceed
//!   `backlog_frac × exec` to grow but fall below a tenth of that to
//!   shrink. A load level inside the gap produces no change forever.
//! * **Hysteresis**: a condition must hold for `dwell_ticks`
//!   CONSECUTIVE ticks before acting, and every action resets all
//!   streaks, so the fastest possible oscillation period is
//!   `2 × dwell_ticks` and one noisy tick resets the clock.
//! * **Bounds**: replicas clamp to `[min_replicas, max_replicas]`, the
//!   batch window to `[min_wait, max_wait]`; a persistent extreme pegs
//!   the decision at a bound and holds it there (a fixed point).

use std::time::Duration;

/// Bounds and gains of the feedback loop. The defaults are deliberately
/// conservative (act after 3 consistent ticks, one step at a time).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ControllerConfig {
    /// Replica-count bounds per lane.
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Batch-window bounds.
    pub min_wait: Duration,
    pub max_wait: Duration,
    /// Consecutive ticks a condition must hold before a change.
    pub dwell_ticks: u32,
    /// Backlog when mean queue wait exceeds this fraction of mean exec
    /// time (work is waiting longer than a good share of its service
    /// time — more parallelism pays).
    pub backlog_frac: f64,
    /// Backlog when the interval shed rate exceeds this.
    pub shed_high: f64,
    /// Batches are "sparse" when mean rows per batch is below this
    /// fraction of `max_batch` — widening the window coalesces better.
    pub sparse_batch_frac: f64,
    /// Controller tick period (used by the coordinator's ticker thread,
    /// carried here so one struct configures the whole loop).
    pub tick: Duration,
}

impl Default for ControllerConfig {
    fn default() -> ControllerConfig {
        ControllerConfig {
            min_replicas: 1,
            max_replicas: 8,
            min_wait: Duration::from_micros(500),
            max_wait: Duration::from_millis(8),
            dwell_ticks: 3,
            backlog_frac: 0.5,
            shed_high: 0.01,
            sparse_batch_frac: 0.25,
            tick: Duration::from_millis(100),
        }
    }
}

/// What one lane did during one controller tick — DELTAS over the tick,
/// not cumulative totals.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LaneObservation {
    /// Requests admitted this tick.
    pub requests: u64,
    /// Requests shed this tick (queue full + deadline).
    pub shed: u64,
    /// Mean queue wait of this tick's batches, microseconds.
    pub queue_mean_us: f64,
    /// Mean execution time of this tick's batches, microseconds.
    pub exec_mean_us: f64,
    /// Mean rows per executed batch this tick.
    pub mean_rows: f64,
    /// The lane's configured batch capacity.
    pub max_batch: usize,
}

impl LaneObservation {
    fn shed_rate(&self) -> f64 {
        let offered = self.requests + self.shed;
        if offered == 0 {
            0.0
        } else {
            self.shed as f64 / offered as f64
        }
    }
}

/// The controller's current targets for one lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decision {
    pub replicas: usize,
    pub wait: Duration,
}

/// Per-lane feedback controller; one instance per model lane.
#[derive(Debug)]
pub struct Controller {
    cfg: ControllerConfig,
    current: Decision,
    up_streak: u32,
    down_streak: u32,
    widen_streak: u32,
    narrow_streak: u32,
}

impl Controller {
    /// Start from the lane's launch configuration, clamped into bounds.
    pub fn new(cfg: ControllerConfig, replicas: usize, wait: Duration) -> Controller {
        let current = Decision {
            replicas: replicas.clamp(cfg.min_replicas, cfg.max_replicas),
            wait: wait.clamp(cfg.min_wait, cfg.max_wait),
        };
        Controller {
            cfg,
            current,
            up_streak: 0,
            down_streak: 0,
            widen_streak: 0,
            narrow_streak: 0,
        }
    }

    pub fn current(&self) -> Decision {
        self.current
    }

    pub fn config(&self) -> &ControllerConfig {
        &self.cfg
    }

    /// Consume one tick's observation, return the (possibly updated)
    /// targets. At most one replica step and one window step per call.
    pub fn step(&mut self, obs: &LaneObservation) -> Decision {
        let cfg = self.cfg;
        if obs.requests + obs.shed == 0 {
            // Idle tick: hold everything and restart the evidence clock.
            // (Scaling down on silence would make cold lanes thrash on
            // the next burst; idle replicas park in a condvar wait.)
            self.reset_replica_streaks();
            self.reset_window_streaks();
            return self.current;
        }

        // --- replica count -------------------------------------------------
        let backlog = obs.shed_rate() > cfg.shed_high
            || obs.queue_mean_us > cfg.backlog_frac * obs.exec_mean_us;
        // Deadband: "light" is 10x stricter than "not backlogged".
        let light = obs.shed == 0 && obs.queue_mean_us < 0.1 * cfg.backlog_frac * obs.exec_mean_us;
        if backlog {
            self.down_streak = 0;
            self.up_streak += 1;
            if self.up_streak >= cfg.dwell_ticks && self.current.replicas < cfg.max_replicas {
                self.current.replicas += 1;
                self.reset_replica_streaks();
            }
        } else if light {
            self.up_streak = 0;
            self.down_streak += 1;
            if self.down_streak >= cfg.dwell_ticks && self.current.replicas > cfg.min_replicas {
                self.current.replicas -= 1;
                self.reset_replica_streaks();
            }
        } else {
            self.reset_replica_streaks();
        }

        // --- batch window --------------------------------------------------
        // Sparse batches with headroom: widen to coalesce. Queue-dominated
        // latency: narrow so admitted work ships sooner. The conditions
        // are mutually exclusive (sparse requires !backlog).
        let sparse = !backlog
            && obs.max_batch > 1
            && obs.mean_rows < cfg.sparse_batch_frac * obs.max_batch as f64;
        let queue_bound = backlog && obs.queue_mean_us > obs.exec_mean_us;
        if sparse {
            self.narrow_streak = 0;
            self.widen_streak += 1;
            if self.widen_streak >= cfg.dwell_ticks && self.current.wait < cfg.max_wait {
                self.current.wait = (self.current.wait * 2).clamp(cfg.min_wait, cfg.max_wait);
                self.reset_window_streaks();
            }
        } else if queue_bound {
            self.widen_streak = 0;
            self.narrow_streak += 1;
            if self.narrow_streak >= cfg.dwell_ticks && self.current.wait > cfg.min_wait {
                self.current.wait = (self.current.wait / 2).clamp(cfg.min_wait, cfg.max_wait);
                self.reset_window_streaks();
            }
        } else {
            self.reset_window_streaks();
        }

        self.current
    }

    fn reset_replica_streaks(&mut self) {
        self.up_streak = 0;
        self.down_streak = 0;
    }

    fn reset_window_streaks(&mut self) {
        self.widen_streak = 0;
        self.narrow_streak = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ControllerConfig {
        ControllerConfig::default()
    }

    fn overload() -> LaneObservation {
        LaneObservation {
            requests: 90,
            shed: 10,
            queue_mean_us: 5000.0,
            exec_mean_us: 1000.0,
            mean_rows: 7.5,
            max_batch: 8,
        }
    }

    fn idle_ish() -> LaneObservation {
        LaneObservation {
            requests: 5,
            shed: 0,
            queue_mean_us: 1.0,
            exec_mean_us: 1000.0,
            mean_rows: 1.0,
            max_batch: 8,
        }
    }

    #[test]
    fn scales_up_under_sustained_backlog_and_respects_the_bound() {
        let mut c = Controller::new(cfg(), 2, Duration::from_millis(2));
        let mut d = c.current();
        for _ in 0..100 {
            d = c.step(&overload());
            assert!(d.replicas <= cfg().max_replicas);
        }
        assert_eq!(d.replicas, cfg().max_replicas); // pegged, not oscillating
        // Queue-bound overload also narrows the window to the floor.
        assert_eq!(d.wait, cfg().min_wait);
    }

    #[test]
    fn scales_down_when_light_and_holds_at_min() {
        let mut c = Controller::new(cfg(), 4, Duration::from_millis(2));
        let mut d = c.current();
        for _ in 0..100 {
            d = c.step(&idle_ish());
            assert!(d.replicas >= cfg().min_replicas);
        }
        assert_eq!(d.replicas, cfg().min_replicas);
        // Sparse batches widened the window to the ceiling.
        assert_eq!(d.wait, cfg().max_wait);
    }

    #[test]
    fn change_needs_dwell_consecutive_ticks() {
        let mut c = Controller::new(cfg(), 2, Duration::from_millis(2));
        // dwell-1 backlogged ticks, then a calm one: no change ever.
        for _ in 0..(cfg().dwell_ticks - 1) {
            assert_eq!(c.step(&overload()).replicas, 2);
        }
        let calm = LaneObservation {
            requests: 50,
            shed: 0,
            queue_mean_us: 300.0, // inside the deadband
            exec_mean_us: 1000.0,
            mean_rows: 4.0,
            max_batch: 8,
        };
        assert_eq!(c.step(&calm).replicas, 2);
        // The streak restarted: dwell-1 more backlog ticks still hold.
        for _ in 0..(cfg().dwell_ticks - 1) {
            assert_eq!(c.step(&overload()).replicas, 2);
        }
        assert_eq!(c.step(&overload()).replicas, 3);
    }

    #[test]
    fn deadband_load_is_a_fixed_point() {
        let mut c = Controller::new(cfg(), 3, Duration::from_millis(2));
        let steady = LaneObservation {
            requests: 100,
            shed: 0,
            queue_mean_us: 300.0, // between 0.1*frac*exec=50 and frac*exec=500
            exec_mean_us: 1000.0,
            mean_rows: 4.0, // above sparse_batch_frac * 8 = 2
            max_batch: 8,
        };
        let before = c.current();
        for _ in 0..50 {
            assert_eq!(c.step(&steady), before);
        }
    }

    #[test]
    fn idle_ticks_hold_state() {
        let mut c = Controller::new(cfg(), 3, Duration::from_millis(2));
        let before = c.current();
        for _ in 0..20 {
            assert_eq!(c.step(&LaneObservation::default()), before);
        }
    }

    #[test]
    fn new_clamps_launch_config_into_bounds() {
        let c = Controller::new(cfg(), 100, Duration::from_secs(10));
        assert_eq!(c.current().replicas, cfg().max_replicas);
        assert_eq!(c.current().wait, cfg().max_wait);
    }
}
