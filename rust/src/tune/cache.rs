//! Tuning-result store: winner configs keyed by (model digest, GEMM
//! shapes, ISA, nthreads), so measurement is paid once per deployment.
//!
//! The store is process-global and in-memory; when `PQDL_TUNE_CACHE`
//! names a file it is loaded once at first use and appended to on every
//! store, so the cache survives restarts (a deployment tunes on first
//! boot, every later boot is a pure cache hit). The format is one text
//! line per entry — human-diffable, no serde needed offline:
//!
//! ```text
//! v1 <digest-hex> <shapes> <isa> <nthreads> <kc> <nr> <par_min_work> <par_min_rows>
//! ```
//!
//! where `<shapes>` is a comma-joined, kind-prefixed `k`x`out` list
//! (e.g. `b64x32,a27x8`). The first five fields ARE the key: change any
//! of model weights (digest), GEMM shapes, ISA, or thread count and the
//! entry no longer matches — invalidation is structural, not TTL-based.
//! Round-trip + invalidation are covered by `tests/tuner.rs`.

use super::GemmConfig;
use crate::onnx::{model_to_json, Model};
use crate::ops::Isa;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};

/// FNV-1a 64-bit over a byte stream.
fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Digest of everything that affects a compiled plan's tuned kernels:
/// the full model — graph structure AND initializer bytes — via the
/// bit-exact JSON serialization (f16 as raw bits, round-trip decimal
/// floats). Two models digest equal iff they serialize equal, so a
/// changed weight invalidates cached tuning the same way a changed
/// graph does.
pub fn model_digest(model: &Model) -> u64 {
    fnv1a(0xcbf2_9ce4_8422_2325, model_to_json(model).as_bytes())
}

/// Counters that make cache behavior observable — the
/// "second `Session::new` must hit the cache without re-measuring"
/// acceptance test reads these, and the CI cache-hit smoke asserts
/// `measurements` does not grow across a second plan compile.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TuneCacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Times the tuner actually ran a measurement sweep (cache misses in
    /// `full` mode).
    pub measurements: u64,
}

static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);
static MEASUREMENTS: AtomicU64 = AtomicU64::new(0);

/// Process-wide cache counters (monotonic; never reset).
pub fn stats() -> TuneCacheStats {
    TuneCacheStats {
        hits: HITS.load(Ordering::Relaxed),
        misses: MISSES.load(Ordering::Relaxed),
        measurements: MEASUREMENTS.load(Ordering::Relaxed),
    }
}

pub(crate) fn count_measurement() {
    MEASUREMENTS.fetch_add(1, Ordering::Relaxed);
}

/// In-memory winner store with an optional line-format disk mirror.
/// Construct directly for tests ([`TuneCache::new`]); production code
/// uses [`TuneCache::global`], whose disk path comes from
/// `PQDL_TUNE_CACHE`.
#[derive(Default)]
pub struct TuneCache {
    map: Mutex<HashMap<String, GemmConfig>>,
    /// Disk mirror path; `None` = memory only.
    path: Option<std::path::PathBuf>,
    load_once: Once,
}

impl TuneCache {
    pub fn new(path: Option<std::path::PathBuf>) -> TuneCache {
        TuneCache {
            map: Mutex::new(HashMap::new()),
            path,
            load_once: Once::new(),
        }
    }

    /// The process-global cache. The disk mirror is read from
    /// `PQDL_TUNE_CACHE` once — the same warm-once discipline as every
    /// other knob, so steady-state serving never touches the
    /// environment.
    pub fn global() -> &'static TuneCache {
        static CACHE: OnceLock<TuneCache> = OnceLock::new();
        CACHE.get_or_init(|| TuneCache::new(std::env::var_os("PQDL_TUNE_CACHE").map(Into::into)))
    }

    fn ensure_loaded(&self) {
        self.load_once.call_once(|| {
            let Some(path) = &self.path else { return };
            let Ok(text) = std::fs::read_to_string(path) else {
                return; // absent/unreadable file = empty cache
            };
            let mut map = self.map.lock().unwrap();
            for line in text.lines() {
                if let Some((key, cfg)) = parse_line(line) {
                    // Later lines win: appends overwrite earlier entries.
                    map.insert(key, cfg);
                }
            }
        });
    }

    /// Look up a winner; counts a hit or miss.
    pub fn lookup(&self, key: &str) -> Option<GemmConfig> {
        self.ensure_loaded();
        let got = self.map.lock().unwrap().get(key).copied();
        match got {
            Some(_) => HITS.fetch_add(1, Ordering::Relaxed),
            None => MISSES.fetch_add(1, Ordering::Relaxed),
        };
        got
    }

    /// Store a winner; appends to the disk mirror when configured.
    /// Disk write failures are non-fatal (the in-memory entry still
    /// serves this process; next boot re-measures).
    pub fn store(&self, key: &str, cfg: GemmConfig) {
        self.ensure_loaded();
        self.map.lock().unwrap().insert(key.to_string(), cfg);
        if let Some(path) = &self.path {
            use std::io::Write;
            let line = format_line(key, cfg);
            let res = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .and_then(|mut f| writeln!(f, "{line}"));
            if let Err(e) = res {
                eprintln!("[pqdl-tune] cache append to {} failed: {e}", path.display());
            }
        }
    }

    /// Number of distinct keys currently held (test observability).
    pub fn len(&self) -> usize {
        self.ensure_loaded();
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Canonical key line: `v1 <digest-hex> <shapes> <isa> <nthreads>`.
/// `shapes` entries are pre-encoded by the tuner (kind-prefixed, comma
/// joined, space free) and must arrive sorted for determinism.
pub fn key_line(digest: u64, shapes: &[String], isa: Isa, nthreads: usize) -> String {
    let joined = if shapes.is_empty() {
        "-".to_string()
    } else {
        shapes.join(",")
    };
    format!("v1 {digest:016x} {joined} {} {nthreads}", isa.name())
}

fn format_line(key: &str, cfg: GemmConfig) -> String {
    format!(
        "{key} {} {} {} {}",
        cfg.kc, cfg.nr, cfg.par_min_work, cfg.par_min_rows
    )
}

/// Parse one disk line into (key, config); `None` on any malformed or
/// differently-versioned line (forward compatible: unknown lines are
/// skipped, never an error).
fn parse_line(line: &str) -> Option<(String, GemmConfig)> {
    let fields: Vec<&str> = line.split_whitespace().collect();
    if fields.len() != 9 || fields[0] != "v1" {
        return None;
    }
    let key = fields[..5].join(" ");
    let cfg = GemmConfig {
        kc: fields[5].parse().ok()?,
        nr: fields[6].parse().ok()?,
        par_min_work: fields[7].parse().ok()?,
        par_min_rows: fields[8].parse().ok()?,
    };
    Some((key, cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::Figure;

    #[test]
    fn digest_is_stable_and_weight_sensitive() {
        let m1 = Figure::Fig1FcTwoMul.model();
        let m2 = Figure::Fig1FcTwoMul.model();
        assert_eq!(model_digest(&m1), model_digest(&m2));
        let other = Figure::Fig2FcReluOneMul.model();
        assert_ne!(model_digest(&m1), model_digest(&other));
    }

    #[test]
    fn line_round_trip() {
        let key = key_line(0xDEAD_BEEF, &["b64x32".into(), "a27x8".into()], Isa::Scalar, 4);
        let cfg = GemmConfig {
            kc: 512,
            nr: 16,
            par_min_work: 16 * 1024,
            par_min_rows: 2,
        };
        let (k2, c2) = parse_line(&format_line(&key, cfg)).expect("round trip");
        assert_eq!(k2, key);
        assert_eq!(c2, cfg);
        assert_eq!(parse_line("v0 junk"), None);
        assert_eq!(parse_line(""), None);
        assert_eq!(parse_line("v1 x y z"), None);
    }

    #[test]
    fn memory_store_lookup() {
        let c = TuneCache::new(None);
        let key = key_line(1, &["b8x8".into()], Isa::Scalar, 1);
        assert_eq!(c.lookup(&key), None);
        let cfg = GemmConfig {
            kc: 128,
            ..GemmConfig::DEFAULT
        };
        c.store(&key, cfg);
        assert_eq!(c.lookup(&key), Some(cfg));
        // A different nthreads is a different key.
        let key2 = key_line(1, &["b8x8".into()], Isa::Scalar, 2);
        assert_eq!(c.lookup(&key2), None);
    }
}
