//! Execution backends behind one trait: the generic interpreter, the
//! integer hardware simulator, and the XLA/PJRT artifacts. The
//! coordinator routes and batches without knowing which is which —
//! exactly the portability story of the paper (one model file, many
//! inference environments).

use super::validate::InputSpec;
use crate::hwsim::{CostReport, HwConfig, HwModule};
use crate::interp::Session;
use crate::onnx::Model;
use crate::parallel::lock_recover;
use crate::runtime::PjrtService;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::sync::{Arc, Mutex};

/// A batched inference engine for one model.
pub trait Backend: Send + Sync {
    fn name(&self) -> &str;
    /// Execute a batch (axis 0 = batch).
    ///
    /// The serving worker treats this call as untrusted: an `Err` is a
    /// typed per-batch failure, and a PANIC is caught (`catch_unwind`),
    /// answered as `ServeError::BackendPanic`, and isolated to the one
    /// batch — implementations therefore need not uphold any
    /// cross-batch invariant across a panic, but any internal locks
    /// should recover from poisoning (see
    /// [`crate::parallel::lock_recover`]) since a panicking call CAN
    /// leave them poisoned for the next batch.
    fn run_batch(&self, input: &Tensor) -> Result<Tensor>;

    /// A cheap per-replica handle over the SAME compiled state, owning
    /// only its own mutable scratch — what a lane spawns one of per
    /// worker. `None` (the default) means the backend has no per-replica
    /// state worth isolating and every replica may share `self` directly;
    /// `run_batch` must then tolerate concurrent callers (all three
    /// built-in backends do).
    fn fork_replica(&self) -> Option<Arc<dyn Backend>> {
        None
    }

    /// The admission contract for this lane, when the backend can state
    /// one: the coordinator checks each request against it at `submit`,
    /// rejecting malformed tensors with a typed `InvalidInput` BEFORE
    /// they can poison a fused batch. `None` disables admission
    /// validation (requests then fail, batched, at execution).
    fn input_spec(&self) -> Option<InputSpec> {
        None
    }
}

/// Interpreter backend ("standard tool" path). `Session::new` compiled
/// the model into an execution plan once; serving a batch is a plan run
/// over the borrowed input — no per-request name resolution or feed
/// clone, and the session's internal scratch-arena pool recycles every
/// intermediate buffer across requests (the output tensor itself is the
/// only steady-state allocation here, because its ownership leaves with
/// the response; callers that can hand buffers back should use
/// `Session::run_into` directly).
pub struct InterpBackend {
    session: Session,
    input_name: String,
    spec: Option<InputSpec>,
}

impl InterpBackend {
    pub fn new(model: Model) -> Result<InterpBackend> {
        let spec = InputSpec::from_model(&model);
        let session = Session::new(model).map_err(|e| anyhow!("{e}"))?;
        let input_name = session
            .model()
            .graph
            .runtime_inputs()
            .first()
            .map(|vi| vi.name.clone())
            .ok_or_else(|| anyhow!("model has no inputs"))?;
        Ok(InterpBackend {
            session,
            input_name,
            spec,
        })
    }

    /// Fusion coverage of the lane's compiled plan (the plan every
    /// replica shares — see [`Backend::fork_replica`]). Printed by
    /// `examples/serve_demo.rs` so coverage is observable in serving.
    pub fn plan_stats(&self) -> crate::interp::PlanStats {
        self.session.plan_stats()
    }
}

impl Backend for InterpBackend {
    fn name(&self) -> &str {
        "interp"
    }

    fn run_batch(&self, input: &Tensor) -> Result<Tensor> {
        let mut out = self
            .session
            .run_refs(&[(self.input_name.as_str(), input)])
            .map_err(|e| anyhow!("{e}"))?;
        Ok(out.remove(0))
    }

    /// Replicas share one `CompiledPlan` (and the model's weights) via
    /// [`Session::fork_replica`] — each costs a handful of `Arc` bumps
    /// plus the scratch arenas it warms up, and replicas never contend on
    /// each other's arena pool locks. Since the plan-time optimizer, the
    /// shared plan is the FUSED one: every replica serves the fused
    /// quantized kernels (and the shared unfused plan exists only for
    /// observation/oracle paths).
    fn fork_replica(&self) -> Option<Arc<dyn Backend>> {
        Some(Arc::new(InterpBackend {
            session: self.session.fork_replica(),
            input_name: self.input_name.clone(),
            spec: self.spec.clone(),
        }))
    }

    fn input_spec(&self) -> Option<InputSpec> {
        self.spec.clone()
    }
}

/// Hardware-simulator backend (integer-only path) with accumulated cost.
pub struct HwSimBackend {
    module: HwModule,
    total_cost: Mutex<CostReport>,
    spec: Option<InputSpec>,
}

impl HwSimBackend {
    pub fn new(model: &Model, cfg: HwConfig) -> Result<HwSimBackend> {
        Ok(HwSimBackend {
            module: HwModule::compile(model, cfg).map_err(|e| anyhow!("{e}"))?,
            total_cost: Mutex::new(CostReport::default()),
            spec: InputSpec::from_model(model),
        })
    }

    /// Total accumulated cost across all served batches.
    pub fn total_cost(&self) -> CostReport {
        lock_recover(&self.total_cost).clone()
    }
}

impl Backend for HwSimBackend {
    fn name(&self) -> &str {
        "hwsim"
    }

    fn run_batch(&self, input: &Tensor) -> Result<Tensor> {
        let (out, cost) = self.module.run(input).map_err(|e| anyhow!("{e}"))?;
        lock_recover(&self.total_cost).add(&cost);
        Ok(out)
    }

    // No `fork_replica`: the module is stateless during `run` and the
    // cost accumulator is meant to aggregate across all replicas of the
    // lane, so replicas share `self`.

    fn input_spec(&self) -> Option<InputSpec> {
        self.spec.clone()
    }
}

/// PJRT backend over the AOT artifacts (via the thread-confined
/// [`PjrtService`] — the xla handles are not `Send`). Artifacts have
/// fixed batch sizes; requests are padded up to the smallest fitting
/// artifact (or chunked through the largest one).
pub struct PjrtBackend {
    service: PjrtService,
    variant: String,
    batches: Vec<usize>,
}

impl PjrtBackend {
    pub fn new(service: PjrtService, variant: &str) -> Result<PjrtBackend> {
        let batches = service
            .batches(variant)
            .ok_or_else(|| anyhow!("no artifacts for variant '{variant}'"))?
            .to_vec();
        if batches.is_empty() {
            bail!("no artifacts for variant '{variant}'");
        }
        Ok(PjrtBackend {
            service,
            variant: variant.to_string(),
            batches,
        })
    }

    fn run_exact(&self, input: &Tensor, batch: usize) -> Result<Tensor> {
        self.service.run_exact(&self.variant, batch, input.clone())
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn run_batch(&self, input: &Tensor) -> Result<Tensor> {
        let n = *input
            .shape()
            .first()
            .ok_or_else(|| anyhow!("rank-0 input"))?;
        // Exact-size artifact?
        if self.batches.contains(&n) {
            return self.run_exact(input, n);
        }
        let max_b = *self.batches.last().unwrap();
        if n < max_b {
            // Pad up to the smallest artifact >= n.
            let target = *self.batches.iter().find(|&&b| b >= n).unwrap();
            let padded = pad_batch(input, target)?;
            let out = self.run_exact(&padded, target)?;
            slice_batch(&out, n)
        } else {
            // Chunk through the largest artifact.
            let mut outs = Vec::new();
            let mut off = 0;
            while off < n {
                let take = max_b.min(n - off);
                let chunk = slice_batch_range(input, off, take)?;
                let padded = if take == max_b {
                    chunk
                } else {
                    pad_batch(&chunk, max_b)?
                };
                let out = self.run_exact(&padded, max_b)?;
                outs.push(slice_batch(&out, take)?);
                off += take;
            }
            concat_batch_owned(&outs)
        }
    }
}

// --- batch tensor manipulation --------------------------------------------
//
// Thin anyhow-flavored wrappers over the [`Tensor`] row primitives so the
// serving layer, the PJRT padding logic and the batch-parallel executors all
// share one implementation.

/// Concatenate along axis 0. All tensors must share dtype + row shape.
/// Takes references: fusion only reads its parts, so the serving worker
/// can fuse queued request tensors without cloning a single one (the
/// fused buffer is the only allocation — see `tests/alloc_regression.rs`).
pub fn concat_batch(tensors: &[&Tensor]) -> Result<Tensor> {
    Ok(Tensor::concat_rows_refs(tensors)?)
}

/// [`concat_batch`] over owned tensors, for callers that already hold a
/// `Vec<Tensor>` (the PJRT chunking path).
pub fn concat_batch_owned(tensors: &[Tensor]) -> Result<Tensor> {
    Ok(Tensor::concat_rows(tensors)?)
}

/// Split along axis 0 into chunks of the given sizes.
pub fn split_batch(t: &Tensor, sizes: &[usize]) -> Result<Vec<Tensor>> {
    let mut out = Vec::with_capacity(sizes.len());
    let mut off = 0usize;
    for &n in sizes {
        out.push(slice_batch_range(t, off, n)?);
        off += n;
    }
    if off != t.shape()[0] {
        bail!("split sizes {:?} != batch {}", sizes, t.shape()[0]);
    }
    Ok(out)
}

/// First `n` rows.
pub fn slice_batch(t: &Tensor, n: usize) -> Result<Tensor> {
    slice_batch_range(t, 0, n)
}

/// Rows [off, off+n).
pub fn slice_batch_range(t: &Tensor, off: usize, n: usize) -> Result<Tensor> {
    Ok(t.slice_rows(off, n)?)
}

/// Pad with zero rows up to `target` rows.
pub fn pad_batch(t: &Tensor, target: usize) -> Result<Tensor> {
    let n = t.shape()[0];
    if target < n {
        bail!("pad target {target} < batch {n}");
    }
    if target == n {
        return Ok(t.clone());
    }
    let mut shape = vec![target - n];
    shape.extend_from_slice(&t.shape()[1..]);
    let zeros = Tensor::zeros(t.dtype(), &shape);
    concat_batch(&[t, &zeros])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::Figure;

    #[test]
    fn concat_split_round_trip() {
        let a = Tensor::from_i8(&[2, 3], vec![1, 2, 3, 4, 5, 6]).unwrap();
        let b = Tensor::from_i8(&[1, 3], vec![7, 8, 9]).unwrap();
        let c = concat_batch(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[3, 3]);
        let parts = split_batch(&c, &[2, 1]).unwrap();
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
        // The owned-slice form agrees.
        let c2 = concat_batch_owned(&[a, b]).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn concat_rejects_mismatch() {
        let a = Tensor::from_i8(&[1, 3], vec![1, 2, 3]).unwrap();
        let b = Tensor::from_i8(&[1, 2], vec![1, 2]).unwrap();
        assert!(concat_batch(&[&a, &b]).is_err());
        let c = Tensor::from_u8(&[1, 3], vec![1, 2, 3]).unwrap();
        assert!(concat_batch(&[&a, &c]).is_err());
    }

    #[test]
    fn pad_and_slice() {
        let a = Tensor::from_i8(&[2, 2], vec![1, 2, 3, 4]).unwrap();
        let p = pad_batch(&a, 4).unwrap();
        assert_eq!(p.shape(), &[4, 2]);
        assert_eq!(p.as_i8().unwrap()[4..], [0, 0, 0, 0]);
        let s = slice_batch(&p, 2).unwrap();
        assert_eq!(s, a);
    }

    #[test]
    fn interp_backend_batching_transparent() {
        let fig = Figure::Fig1FcTwoMul;
        let be = InterpBackend::new(fig.model()).unwrap();
        let x = fig.input(4, 11);
        let whole = be.run_batch(&x).unwrap();
        // Per-row execution must give identical rows.
        for i in 0..4 {
            let row = slice_batch_range(&x, i, 1).unwrap();
            let out = be.run_batch(&row).unwrap();
            assert_eq!(
                out.as_i8().unwrap(),
                &whole.as_i8().unwrap()[i * 32..(i + 1) * 32]
            );
        }
    }

    #[test]
    fn interp_replica_is_bit_identical_and_keeps_the_spec() {
        let fig = Figure::Fig1FcTwoMul;
        let be = InterpBackend::new(fig.model()).unwrap();
        let replica = be.fork_replica().expect("interp forks replicas");
        let spec = replica.input_spec().expect("interp lanes have a spec");
        let x = fig.input(3, 5);
        assert!(spec.check(&x).is_ok());
        assert_eq!(
            be.run_batch(&x).unwrap(),
            replica.run_batch(&x).unwrap()
        );
        let bad = Tensor::from_f32(&[1, 64], vec![0.0; 64]).unwrap();
        assert!(spec.check(&bad).is_err());
    }

    #[test]
    fn hwsim_backend_accumulates_cost() {
        let fig = Figure::Fig1FcTwoMul;
        let be = HwSimBackend::new(&fig.model(), HwConfig::default()).unwrap();
        be.run_batch(&fig.input(2, 1)).unwrap();
        be.run_batch(&fig.input(2, 2)).unwrap();
        let cost = be.total_cost();
        assert_eq!(cost.macs, 2 * 2 * 64 * 32);
    }
}
