//! The serving loop: sharded replica pools with admission control.
//!
//! Each model lane owns a **bounded** queue and N **replica** workers
//! pulling from it (`ServerConfig::replicas`); interpreter replicas share
//! one compiled plan via [`Session::fork_replica`](crate::interp::Session::fork_replica),
//! so a replica costs a few `Arc` bumps plus the scratch it warms up.
//!
//! Admission control happens at [`Coordinator::submit`]: requests are
//! validated against the lane's [`InputSpec`] (dtype/rank/fixed dims) and
//! shed with a typed [`RejectReason`] when malformed, when the lane queue
//! is at its depth cap, or — at dequeue — when their per-request deadline
//! has already passed. A shed request still receives exactly one
//! [`Response`], and nothing queues unboundedly. On lanes whose backend
//! states an `InputSpec` (interpreter and hwsim do) a bad tensor is
//! rejected alone and can never poison a fused batch; spec-less lanes
//! (PJRT, whose artifacts carry no model signature) still fail such a
//! batch at execution, with a typed `Exec` error.
//!
//! Size + deadline batching policy: a replica takes the first queued
//! request, then keeps admitting while the TOTAL fused row count stays
//! within `max_batch` (row counts are peeked before admission — a
//! multi-row request that would overshoot is deferred to open the next
//! batch) and `max_wait` has not elapsed; the batch is fused along axis 0
//! (the models' symbolic `N`) without cloning any input, executed once,
//! and split back per request.
//!
//! Shutdown is graceful by default: [`Coordinator::shutdown`] closes
//! intake, drains every queued request, and joins the replicas;
//! [`Coordinator::shutdown_now`] is the old hard stop (queued requests
//! get channel errors).
//!
//! Fault tolerance: the fuse/execute/split step is unwind-isolated
//! (`catch_unwind`), so a panicking kernel answers its batch with a
//! typed [`ServeError::BackendPanic`] instead of killing the worker;
//! every coordinator lock goes through
//! [`crate::parallel::lock_recover`], so no panic can poison `submit`
//! or sibling replicas; [`ServerConfig::breaker`] adds a per-lane
//! circuit breaker shedding [`RejectReason::CircuitOpen`] while the
//! backend is sick; [`ServerConfig::supervisor`] adds heartbeat-based
//! replica supervision with exponential-backoff respawns (both opt-in,
//! default off). The deterministic fault-injection harness that drives
//! all of this in tests lives in [`super::fault`].

use super::backend::{concat_batch, split_batch, Backend};
use super::breaker::{BreakerConfig, CircuitBreaker};
use super::fault::{panic_message, ReplicaAbort};
use super::metrics::{BatchFate, FaultEvent, LatencyHist, Metrics, ModelStats, ShedKind};
use super::validate::InputSpec;
use crate::parallel::{lock_recover, wait_timeout_recover};
use crate::tensor::Tensor;
use crate::tune::{Controller, ControllerConfig, LaneObservation};
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serving configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Maximum rows fused into one execution (a single request larger
    /// than this still runs, alone).
    pub max_batch: usize,
    /// Maximum time a batch stays open waiting for more requests.
    pub max_wait: Duration,
    /// Worker replicas per model lane, all pulling from the lane's shared
    /// queue. Interpreter replicas share one compiled plan. `0` (the
    /// default) means auto: the machine-level [`default_replicas`] budget
    /// divided evenly across the registered lanes, so multi-model
    /// coordinators do not oversubscribe the machine.
    pub replicas: usize,
    /// Lane queue depth cap: a submit finding this many requests queued
    /// is shed immediately with [`RejectReason::QueueFull`].
    pub queue_depth: usize,
    /// Per-request deadline, measured from `submit`. A request whose
    /// deadline has passed by the time a replica would execute it is shed
    /// with [`RejectReason::DeadlineExceeded`] instead of running late.
    /// `None` disables deadline shedding.
    pub deadline: Option<Duration>,
    /// Serving-time feedback controller ([`crate::tune::controller`]):
    /// when set, a ticker thread diffs the live metrics every
    /// `ControllerConfig::tick` and steers each lane's active replica
    /// count (within the controller's bounds — workers above the target
    /// park on the lane condvar, holding no work) and its batch window
    /// (replacing `max_wait` as the live value; `max_wait` becomes the
    /// launch point, clamped into the controller's window bounds).
    /// `None` (the default) keeps both fixed at their configured values.
    pub controller: Option<ControllerConfig>,
    /// Per-lane circuit breaker ([`super::breaker`]): after
    /// `failures_to_open` consecutive failed batches the lane sheds
    /// instantly with [`RejectReason::CircuitOpen`] for a cooldown, then
    /// re-admits a few probe requests before closing again. `None` (the
    /// default) disables breaking.
    pub breaker: Option<BreakerConfig>,
    /// Replica supervision: when set, the lane ticker thread watches
    /// per-worker heartbeats, counts wedged replicas, and respawns dead
    /// ones under an exponential-backoff restart budget. `None` (the
    /// default) leaves worker death permanent (pre-fault behavior).
    pub supervisor: Option<SupervisorConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            replicas: 0, // auto: default_replicas() split across lanes
            queue_depth: 256,
            deadline: None,
            controller: None,
            breaker: None,
            supervisor: None,
        }
    }
}

/// Supervision knobs ([`ServerConfig::supervisor`]).
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// A live worker silent for longer than this is counted wedged
    /// (stuck inside a backend call it cannot be forced out of — the
    /// counter is the operator signal; the breaker keeps traffic away).
    pub heartbeat_timeout: Duration,
    /// Restart budget per worker slot; once spent the slot is abandoned
    /// (and counted in `ModelStats::restart_budget_exhausted`).
    pub max_restarts: u32,
    /// The backoff before restart k of a slot is `backoff_base * 2^k`,
    /// capped at `backoff_cap` — a crash-looping backend must not be
    /// respawned into at full speed.
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// Supervision scan period (the lane ticker runs at the smallest of
    /// this and the controller tick).
    pub tick: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> SupervisorConfig {
        SupervisorConfig {
            heartbeat_timeout: Duration::from_secs(2),
            max_restarts: 5,
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(2),
            tick: Duration::from_millis(50),
        }
    }
}

/// Machine-level replica budget backing the auto (`replicas: 0`)
/// setting: half the machine's threads (the other half stays available
/// to the kernel-level pool the replicas dispatch into for large
/// batches), at least 1, capped at 8. [`CoordinatorBuilder::start`]
/// divides it evenly across the registered lanes; an explicit
/// `ServerConfig::replicas` value is taken per lane, verbatim.
pub fn default_replicas() -> usize {
    (crate::parallel::default_threads() / 2).clamp(1, 8)
}

/// Why the coordinator refused to execute a request. Every variant is a
/// deliberate, immediate shed — the request was never run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The lane queue was at `ServerConfig::queue_depth`.
    QueueFull,
    /// The request's `ServerConfig::deadline` passed before a replica
    /// could execute it.
    DeadlineExceeded,
    /// The tensor failed the lane's [`InputSpec`] (dtype/rank/dims); the
    /// payload says exactly what mismatched.
    InvalidInput(String),
    /// The lane's circuit breaker was open: the backend failed
    /// `BreakerConfig::failures_to_open` consecutive batches and the
    /// cooldown has not elapsed — shedding fast beats queueing into a
    /// sick lane.
    CircuitOpen,
}

impl RejectReason {
    fn shed_kind(&self) -> ShedKind {
        match self {
            RejectReason::QueueFull => ShedKind::QueueFull,
            RejectReason::DeadlineExceeded => ShedKind::DeadlineExceeded,
            RejectReason::InvalidInput(_) => ShedKind::InvalidInput,
            RejectReason::CircuitOpen => ShedKind::CircuitOpen,
        }
    }
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull => write!(f, "queue full"),
            RejectReason::DeadlineExceeded => write!(f, "deadline exceeded"),
            RejectReason::InvalidInput(msg) => write!(f, "invalid input: {msg}"),
            RejectReason::CircuitOpen => write!(f, "circuit open"),
        }
    }
}

/// What a request's `output` can fail with: a typed admission-control
/// shed (the request never ran) or an execution error (it ran and the
/// backend failed). Callers distinguishing the two is the point — shed
/// load is a policy outcome to retry elsewhere, an `Exec` error is a bug
/// or a poisoned lane to investigate.
#[derive(Clone, Debug, PartialEq, thiserror::Error)]
pub enum ServeError {
    #[error("rejected: {0}")]
    Rejected(RejectReason),
    #[error("execution failed: {0}")]
    Exec(String),
    /// The backend panicked mid-batch; the panic was caught and isolated
    /// (this worker, its siblings, and every coordinator lock survive).
    #[error("backend panicked: {0}")]
    BackendPanic(String),
    /// The serving worker vanished with the request in flight (hard stop
    /// mid-queue, or every replica lost with its restart budget spent).
    #[error("serving worker lost")]
    WorkerLost,
}

/// A completed inference (or a typed refusal to perform one).
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub output: Result<Tensor, ServeError>,
    /// Time spent queued before execution started (for shed requests:
    /// time queued until the shed).
    pub queue_time: Duration,
    /// Execution wall time of the fused batch (zero for shed requests).
    pub exec_time: Duration,
    /// How many REQUESTS were fused into this request's batch (zero for
    /// shed requests).
    pub batch_requests: usize,
    /// How many axis-0 ROWS the fused batch spanned (zero for shed
    /// requests). Diverges from `batch_requests` as soon as any fused
    /// request carries more than one row.
    pub batch_rows: usize,
}

impl Response {
    /// The typed rejection, when this response is a shed.
    pub fn reject_reason(&self) -> Option<&RejectReason> {
        match &self.output {
            Err(ServeError::Rejected(r)) => Some(r),
            _ => None,
        }
    }

    fn rejected(id: u64, reason: RejectReason, queue_time: Duration) -> Response {
        Response {
            id,
            output: Err(ServeError::Rejected(reason)),
            queue_time,
            exec_time: Duration::ZERO,
            batch_requests: 0,
            batch_rows: 0,
        }
    }
}

struct Request {
    id: u64,
    input: Tensor,
    enqueued: Instant,
    /// `enqueued + ServerConfig::deadline`, when one is configured.
    deadline: Option<Instant>,
    resp: mpsc::Sender<Response>,
}

fn rows_of(t: &Tensor) -> usize {
    t.shape().first().copied().unwrap_or(1)
}

struct LaneState {
    queue: VecDeque<Request>,
    /// Intake open: false once a shutdown begins (graceful or hard).
    open: bool,
    /// Hard stop: replicas exit without draining the queue.
    stop: bool,
}

/// The controller's live targets for one lane — written by the ticker,
/// read lock-free by every replica at its next batch (plain launch
/// values, never rewritten, when no controller is configured).
struct LaneDynamics {
    /// Current batch window, microseconds (the live `max_wait`).
    wait_us: AtomicU64,
    /// Replicas allowed to pull work. Workers with index >= this park on
    /// the lane condvar holding nothing; raising it reactivates them
    /// (they were spawned up to the controller's `max_replicas` at
    /// start, so scale-up never spawns threads or re-forks a backend).
    target_replicas: AtomicUsize,
}

impl LaneDynamics {
    fn new(replicas: usize, wait: Duration) -> LaneDynamics {
        LaneDynamics {
            wait_us: AtomicU64::new(wait.as_micros() as u64),
            target_replicas: AtomicUsize::new(replicas.max(1)),
        }
    }

    fn wait(&self) -> Duration {
        Duration::from_micros(self.wait_us.load(Ordering::Relaxed))
    }

    fn replicas(&self) -> usize {
        self.target_replicas.load(Ordering::Relaxed)
    }
}

/// Liveness record for one replica worker slot, shared between the
/// worker (writer) and the supervisor (reader).
#[derive(Default)]
struct WorkerHealth {
    /// Last heartbeat, as microseconds since `Lane::epoch` (an `Instant`
    /// cannot live in an atomic; the offset encoding can).
    heartbeat_us: AtomicU64,
    /// Flipped false by the worker's [`AliveGuard`] drop — i.e. on ANY
    /// exit path: normal return, `ReplicaAbort`, or an escaped unwind.
    alive: AtomicBool,
}

/// One model lane: the bounded queue its replicas share, plus the
/// admission contract checked at submit and the health/breaker state
/// the fault-tolerance layer hangs off it.
struct Lane {
    state: Mutex<LaneState>,
    cv: Condvar,
    spec: Option<InputSpec>,
    dynamics: LaneDynamics,
    /// Time origin for the heartbeat encoding.
    epoch: Instant,
    /// One slot per spawned worker (controller lanes: per ceiling slot).
    health: Vec<WorkerHealth>,
    /// Per-lane circuit breaker ([`ServerConfig::breaker`]; `None` =
    /// off). The mutex is uncontended: admission and batch completion
    /// each hold it for a few integer compares.
    breaker: Option<Mutex<CircuitBreaker>>,
}

impl Lane {
    /// Record a heartbeat for worker slot `idx` (one atomic store —
    /// cheap enough for every loop iteration).
    fn beat(&self, idx: usize) {
        if let Some(h) = self.health.get(idx) {
            h.heartbeat_us
                .store(self.epoch.elapsed().as_micros() as u64, Ordering::SeqCst);
        }
    }
}

/// Flips a worker slot's `alive` flag on ANY thread exit — normal
/// return, `ReplicaAbort`, or an unwind escaping the worker loop — so
/// the supervisor sees dead workers without polling thread handles.
struct AliveGuard {
    lane: Arc<Lane>,
    idx: usize,
}

impl Drop for AliveGuard {
    fn drop(&mut self) {
        if let Some(h) = self.lane.health.get(self.idx) {
            h.alive.store(false, Ordering::SeqCst);
        }
    }
}

/// The coordinator: routes requests to per-model replica pools.
pub struct Coordinator {
    lanes: HashMap<String, Arc<Lane>>,
    cfg: ServerConfig,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// Stops the controller ticker (set by both shutdown flavors; the
    /// ticker's handle lives in `handles` and is joined with the rest).
    ctl_stop: Arc<AtomicBool>,
}

/// Builder registering (model name -> backend) lanes.
pub struct CoordinatorBuilder {
    config: ServerConfig,
    backends: Vec<(String, Arc<dyn Backend>)>,
}

impl CoordinatorBuilder {
    pub fn new(config: ServerConfig) -> CoordinatorBuilder {
        CoordinatorBuilder {
            config,
            backends: Vec::new(),
        }
    }

    /// Register a backend to serve `model`.
    pub fn register(mut self, model: &str, backend: Arc<dyn Backend>) -> Self {
        self.backends.push((model.to_string(), backend));
        self
    }

    /// Spawn the replica pools (and the lane ticker, when a controller
    /// or supervisor is configured) and return the running coordinator.
    pub fn start(self) -> Coordinator {
        let metrics = Arc::new(Metrics::default());
        let mut lanes = HashMap::new();
        let mut handles = Vec::new();
        let ctl_stop = Arc::new(AtomicBool::new(false));
        // replicas = 0 is the auto setting: split the machine-level
        // budget across lanes so a many-model coordinator does not spawn
        // lanes x budget threads.
        let replicas = match self.config.replicas {
            0 => (default_replicas() / self.backends.len().max(1)).max(1),
            n => n,
        };
        // With a controller, spawn workers up to its replica ceiling and
        // let the live target (clamped launch count) decide who pulls
        // work — scale-up later is an atomic store, not a thread spawn.
        let want_ticker = self.config.controller.is_some() || self.config.supervisor.is_some();
        let mut ticker_lanes: Vec<TickerLane> = Vec::new();
        for (model, backend) in self.backends {
            let (workers, controller) = match self.config.controller {
                Some(c) => {
                    let ctl = Controller::new(c, replicas, self.config.max_wait);
                    (c.max_replicas.max(1), Some(ctl))
                }
                None => (replicas, None),
            };
            let launch = controller
                .as_ref()
                .map(|c| c.current())
                .unwrap_or(crate::tune::Decision {
                    replicas,
                    wait: self.config.max_wait,
                });
            let lane = Arc::new(Lane {
                state: Mutex::new(LaneState {
                    queue: VecDeque::new(),
                    open: true,
                    stop: false,
                }),
                cv: Condvar::new(),
                spec: backend.input_spec(),
                dynamics: LaneDynamics::new(launch.replicas, launch.wait),
                epoch: Instant::now(),
                health: (0..workers).map(|_| WorkerHealth::default()).collect(),
                breaker: self
                    .config
                    .breaker
                    .map(|b| Mutex::new(CircuitBreaker::new(b))),
            });
            for r in 0..workers {
                // Replica 0 serves through the registered backend; the
                // rest through cheap forks sharing its compiled state
                // (backends without per-replica state share directly).
                let be = if r == 0 {
                    backend.clone()
                } else {
                    backend.fork_replica().unwrap_or_else(|| backend.clone())
                };
                handles.push(spawn_replica(
                    lane.clone(),
                    be,
                    self.config.clone(),
                    metrics.clone(),
                    model.clone(),
                    r,
                ));
            }
            if want_ticker {
                let sup = match self.config.supervisor {
                    Some(_) => (0..workers).map(|_| SupSlot::default()).collect(),
                    None => Vec::new(),
                };
                ticker_lanes.push(TickerLane {
                    model: model.clone(),
                    lane: lane.clone(),
                    root: backend.clone(),
                    ctl: controller,
                    sup,
                });
            }
            lanes.insert(model, lane);
        }
        if !ticker_lanes.is_empty() {
            let m = metrics.clone();
            let stop = ctl_stop.clone();
            let cfg = self.config.clone();
            let handle = std::thread::Builder::new()
                .name("lane-ticker".into())
                .spawn(move || lane_ticker(ticker_lanes, m, cfg, stop))
                .expect("spawning lane ticker");
            handles.push(handle);
        }
        Coordinator {
            lanes,
            cfg: self.config,
            metrics,
            next_id: AtomicU64::new(1),
            handles: Mutex::new(handles),
            ctl_stop,
        }
    }
}

impl Coordinator {
    /// Submit one request; returns a receiver for its response. Every
    /// accepted submit yields EXACTLY one response on the receiver — a
    /// real output, an execution error, or a typed rejection (shed
    /// requests are answered immediately). `Err` is returned only for an
    /// unknown model or a lane already shut down.
    pub fn submit(&self, model: &str, input: Tensor) -> Result<mpsc::Receiver<Response>> {
        self.submit_inner(model, input).map(|(_, rx)| rx)
    }

    fn submit_inner(
        &self,
        model: &str,
        input: Tensor,
    ) -> Result<(u64, mpsc::Receiver<Response>)> {
        let lane = self
            .lanes
            .get(model)
            .ok_or_else(|| anyhow!("unknown model '{model}'"))?;
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);

        let mut st = lock_recover(&lane.state);
        // Liveness first: a shut-down lane refuses EVERY submission the
        // same way, malformed or not.
        if !st.open {
            return Err(anyhow!("lane for '{model}' is shut down"));
        }
        // Admission-time validation: a malformed tensor is rejected here,
        // alone, before it can be fused with (and fail) anyone else. The
        // check is a handful of dtype/dim comparisons, cheap enough to
        // hold the lane lock across.
        if let Some(spec) = &lane.spec {
            if let Err(msg) = spec.check(&input) {
                drop(st);
                let reason = RejectReason::InvalidInput(msg);
                self.metrics.record_shed(model, reason.shed_kind());
                let _ = tx.send(Response::rejected(id, reason, Duration::ZERO));
                return Ok((id, rx));
            }
        }
        // Circuit breaker AFTER validation, so malformed inputs keep
        // their deterministic InvalidInput classification even while the
        // lane's backend is mid-outage.
        if let Some(b) = &lane.breaker {
            if !lock_recover(b).admit(Instant::now()) {
                drop(st);
                let reason = RejectReason::CircuitOpen;
                self.metrics.record_shed(model, reason.shed_kind());
                let _ = tx.send(Response::rejected(id, reason, Duration::ZERO));
                return Ok((id, rx));
            }
        }
        let now = Instant::now();
        // Purge already-expired requests from the queue front before
        // judging capacity: under short deadlines and a busy replica the
        // queue can be full of dead entries, and shedding a live submit
        // as QueueFull against those would both waste capacity and
        // misattribute the shed in the metrics. Deadlines are uniform
        // (config-wide), so expiry order is FIFO and a front sweep
        // suffices; the shed responses go out after the lock is dropped.
        let mut expired: Vec<Request> = Vec::new();
        while st.queue.front().is_some_and(|r| past_deadline(r, now)) {
            expired.push(st.queue.pop_front().expect("front checked"));
        }
        if st.queue.len() >= self.cfg.queue_depth.max(1) {
            drop(st);
            shed_expired(&mut expired, &self.metrics, model);
            let reason = RejectReason::QueueFull;
            self.metrics.record_shed(model, reason.shed_kind());
            let _ = tx.send(Response::rejected(id, reason, Duration::ZERO));
            return Ok((id, rx));
        }
        st.queue.push_back(Request {
            id,
            input,
            enqueued: now,
            deadline: self.cfg.deadline.map(|d| now + d),
            resp: tx,
        });
        drop(st);
        shed_expired(&mut expired, &self.metrics, model);
        lane.cv.notify_one();
        Ok((id, rx))
    }

    /// Convenience: submit and wait. A worker dying with the request in
    /// flight (hard stop mid-queue, or every replica lost with its
    /// restart budget spent) surfaces as a typed
    /// [`ServeError::WorkerLost`] response — every failure stays inside
    /// the `ServeError` taxonomy instead of leaking a bare channel
    /// error.
    pub fn infer(&self, model: &str, input: Tensor) -> Result<Response> {
        let (id, rx) = self.submit_inner(model, input)?;
        match rx.recv() {
            Ok(resp) => Ok(resp),
            Err(_) => Ok(Response {
                id,
                output: Err(ServeError::WorkerLost),
                queue_time: Duration::ZERO,
                exec_time: Duration::ZERO,
                batch_requests: 0,
                batch_rows: 0,
            }),
        }
    }

    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.lanes.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// The live (active replicas, batch window) targets of a lane —
    /// launch values until the serving-time controller moves them, or
    /// forever when no controller is configured. Observability for tests
    /// and the serving demo; the hot path reads the same atomics.
    pub fn lane_targets(&self, model: &str) -> Option<(usize, Duration)> {
        let lane = self.lanes.get(model)?;
        Some((lane.dynamics.replicas(), lane.dynamics.wait()))
    }

    /// Graceful shutdown: stop intake, DRAIN every queued request (each
    /// receives a real response), then join the replicas. Blocks until
    /// the drain completes.
    pub fn shutdown(&self) {
        self.ctl_stop.store(true, Ordering::Relaxed);
        for lane in self.lanes.values() {
            lock_recover(&lane.state).open = false;
            lane.cv.notify_all();
        }
        for h in lock_recover(&self.handles).drain(..) {
            let _ = h.join();
        }
        // Normally the workers drained everything before exiting. The
        // exception: every replica of a lane died (restart budget spent,
        // or no supervisor configured) with requests still queued. Those
        // still get their exactly-one response — a typed WorkerLost.
        for lane in self.lanes.values() {
            let leftover: Vec<Request> = lock_recover(&lane.state).queue.drain(..).collect();
            for req in leftover {
                let queue_time = req.enqueued.elapsed();
                let _ = req.resp.send(Response {
                    id: req.id,
                    output: Err(ServeError::WorkerLost),
                    queue_time,
                    exec_time: Duration::ZERO,
                    batch_requests: 0,
                    batch_rows: 0,
                });
            }
        }
    }

    /// Hard stop: stop intake and DROP queued requests (their receivers
    /// observe channel errors — the old hard-shutdown contract). Batches
    /// already executing still complete.
    pub fn shutdown_now(&self) {
        self.ctl_stop.store(true, Ordering::Relaxed);
        for lane in self.lanes.values() {
            let dropped: Vec<Request> = {
                let mut st = lock_recover(&lane.state);
                st.open = false;
                st.stop = true;
                st.queue.drain(..).collect()
            };
            lane.cv.notify_all();
            // Dropping the requests outside the lock drops their response
            // senders; pending receivers error out.
            drop(dropped);
        }
        for h in lock_recover(&self.handles).drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Hard stop, NOT the graceful drain: a drop during unwinding (or
        // a forgotten explicit shutdown) must never block on a slow or
        // wedged backend working through a deep queue. Call
        // [`Coordinator::shutdown`] explicitly to drain.
        self.shutdown_now();
    }
}

/// Respond to requests shed at dequeue because their deadline passed.
fn shed_expired(expired: &mut Vec<Request>, metrics: &Metrics, model: &str) {
    for req in expired.drain(..) {
        let reason = RejectReason::DeadlineExceeded;
        metrics.record_shed(model, reason.shed_kind());
        let queue_time = req.enqueued.elapsed();
        let _ = req
            .resp
            .send(Response::rejected(req.id, reason, queue_time));
    }
}

fn past_deadline(req: &Request, now: Instant) -> bool {
    req.deadline.is_some_and(|d| d <= now)
}

/// Spawn (or respawn) one replica worker for `lane` slot `idx`, marking
/// the slot alive and freshly heartbeaten BEFORE the thread runs so the
/// supervisor never flags a just-spawned worker as dead or stale.
fn spawn_replica(
    lane: Arc<Lane>,
    backend: Arc<dyn Backend>,
    cfg: ServerConfig,
    metrics: Arc<Metrics>,
    model: String,
    idx: usize,
) -> JoinHandle<()> {
    if let Some(h) = lane.health.get(idx) {
        h.heartbeat_us
            .store(lane.epoch.elapsed().as_micros() as u64, Ordering::SeqCst);
        h.alive.store(true, Ordering::SeqCst);
    }
    std::thread::Builder::new()
        .name(format!("lane-{model}-r{idx}"))
        .spawn(move || replica_worker(lane, backend, cfg, metrics, model, idx))
        .expect("spawning lane replica")
}

/// One lane replica: pull the batch-opening request, admit more while the
/// fused ROW count fits `max_batch` (peeked before admission — never
/// overshooting) and the window is open, execute once over borrowed
/// inputs, split, respond. Exits when hard-stopped, when intake is
/// closed and the queue has drained, or when an injected `ReplicaAbort`
/// recycles the thread (the supervisor's restart path).
fn replica_worker(
    lane: Arc<Lane>,
    backend: Arc<dyn Backend>,
    cfg: ServerConfig,
    metrics: Arc<Metrics>,
    model: String,
    idx: usize,
) {
    let _alive = AliveGuard {
        lane: lane.clone(),
        idx,
    };
    let mut expired: Vec<Request> = Vec::new();
    'serve: loop {
        // -- acquire the batch-opening request ---------------------------
        let first = 'acquire: loop {
            let (req, exit) = {
                let mut st = lock_recover(&lane.state);
                loop {
                    lane.beat(idx);
                    if st.stop {
                        break (None, true);
                    }
                    // Parked by the controller: workers above the live
                    // replica target hold no work and wait to be scaled
                    // back in. Only while intake is open — every worker
                    // helps drain a graceful shutdown.
                    if st.open && idx >= lane.dynamics.replicas() {
                        st = wait_timeout_recover(&lane.cv, st, Duration::from_millis(50));
                        continue;
                    }
                    let now = Instant::now();
                    while st.queue.front().is_some_and(|r| past_deadline(r, now)) {
                        expired.push(st.queue.pop_front().expect("front checked"));
                    }
                    if let Some(r) = st.queue.pop_front() {
                        break (Some(r), false);
                    }
                    if !st.open {
                        break (None, true); // drained
                    }
                    if !expired.is_empty() {
                        // Answer the shed requests without holding the lock,
                        // then come back.
                        break (None, false);
                    }
                    st = wait_timeout_recover(&lane.cv, st, Duration::from_millis(50));
                }
            };
            shed_expired(&mut expired, &metrics, &model);
            match (req, exit) {
                (Some(r), _) => break 'acquire r,
                (None, true) => return,
                (None, false) => continue 'acquire,
            }
        };

        // -- admit until the fused rows fill max_batch or the window ends -
        let opened = Instant::now();
        // The live batch window: `cfg.max_wait` unless the controller is
        // steering it. Read once per batch — a mid-batch retarget applies
        // from the next batch.
        let max_wait = lane.dynamics.wait();
        let mut rows = rows_of(&first.input);
        let mut batch = vec![first];
        'fill: while rows < cfg.max_batch {
            let elapsed = opened.elapsed();
            if elapsed >= max_wait {
                break;
            }
            let window = max_wait - elapsed;
            let mut st = lock_recover(&lane.state);
            // At most ONE wait per lock acquisition: `window` is computed
            // from the batch-open time above, so waiting with it twice
            // (e.g. after a wake that admitted a request) would restart
            // the batch window and hold the batch open for up to
            // max_batch x max_wait. After a wait, an empty queue always
            // bounces to 'fill to recompute the remaining window.
            let mut waited = false;
            loop {
                if st.stop {
                    // Hard stop: run what was already claimed, then exit
                    // at the top of 'serve.
                    break 'fill;
                }
                let now = Instant::now();
                // Peek the front request (expiry + row count) before
                // deciding; the borrow ends here so the queue can be
                // popped below.
                let front = st
                    .queue
                    .front()
                    .map(|r| (past_deadline(r, now), rows_of(&r.input)));
                let front_rows = match front {
                    Some((true, _)) => {
                        expired.push(st.queue.pop_front().expect("front checked"));
                        continue;
                    }
                    Some((false, n)) => n,
                    None => {
                        if !st.open {
                            break 'fill; // draining: nothing more arrives
                        }
                        if waited {
                            // Recompute the remaining window (releases
                            // the lock on the way) instead of re-waiting
                            // with the stale one.
                            continue 'fill;
                        }
                        st = wait_timeout_recover(&lane.cv, st, window);
                        waited = true;
                        continue;
                    }
                };
                if rows + front_rows > cfg.max_batch {
                    // THE overshoot fix: row count is peeked BEFORE
                    // admission. A request that would push the fused batch
                    // past max_batch stays queued and opens the next batch
                    // instead of silently inflating this one.
                    break 'fill;
                }
                let r = st.queue.pop_front().expect("front checked");
                rows += front_rows;
                batch.push(r);
                if rows >= cfg.max_batch {
                    break 'fill;
                }
            }
        }
        shed_expired(&mut expired, &metrics, &model);

        // A batch can close leaving work queued (overshoot deferral, or
        // filling up while more requests arrived whose submit-time
        // notifies this worker consumed into the open batch). Wake an
        // idle replica NOW rather than letting that work ride out a poll
        // timeout; a spurious notify is harmless — wakers re-check the
        // queue under the lock.
        lane.cv.notify_one();

        // -- fuse (borrowed — no input clones), execute once, split ------
        // The whole fuse/execute/split is unwind-isolated: a panicking
        // kernel (or a concat/split invariant violation) must cost this
        // ONE batch one typed error — not the worker thread, and (before
        // lock_recover) not every mutex the unwind would have poisoned.
        lane.beat(idx);
        let exec_start = Instant::now();
        let queue_times: Vec<Duration> = batch
            .iter()
            .map(|r| exec_start.duration_since(r.enqueued))
            .collect();
        let sizes: Vec<usize> = batch.iter().map(|r| rows_of(&r.input)).collect();
        let result: std::thread::Result<Result<Vec<Tensor>>> =
            catch_unwind(AssertUnwindSafe(|| {
                let inputs: Vec<&Tensor> = batch.iter().map(|r| &r.input).collect();
                concat_batch(&inputs).and_then(|fused| {
                    let out = backend.run_batch(&fused)?;
                    split_batch(&out, &sizes)
                })
            }));
        let exec_time = exec_start.elapsed();
        lane.beat(idx);
        let batch_requests = batch.len();

        let (fate, abort) = match &result {
            Ok(Ok(_)) => (BatchFate::Success, false),
            Ok(Err(_)) => (BatchFate::Error, false),
            Err(p) => (BatchFate::Panic, p.is::<ReplicaAbort>()),
        };
        metrics.record_batch(&model, batch_requests, rows, &queue_times, exec_time, fate);
        // Breaker feedback: exec errors and panics are lane-sickness
        // signals; a success closes a half-open probe round.
        if let Some(b) = &lane.breaker {
            if lock_recover(b).on_batch(fate == BatchFate::Success, Instant::now()) {
                metrics.record_fault_event(&model, FaultEvent::BreakerOpen);
            }
        }

        match result {
            Ok(Ok(outputs)) => {
                for ((req, out), q) in batch.into_iter().zip(outputs).zip(&queue_times) {
                    let _ = req.resp.send(Response {
                        id: req.id,
                        output: Ok(out),
                        queue_time: *q,
                        exec_time,
                        batch_requests,
                        batch_rows: rows,
                    });
                }
            }
            Ok(Err(e)) => {
                let err = ServeError::Exec(e.to_string());
                for (req, q) in batch.into_iter().zip(&queue_times) {
                    let _ = req.resp.send(Response {
                        id: req.id,
                        output: Err(err.clone()),
                        queue_time: *q,
                        exec_time,
                        batch_requests,
                        batch_rows: rows,
                    });
                }
            }
            Err(payload) => {
                let err = ServeError::BackendPanic(panic_message(payload.as_ref()));
                for (req, q) in batch.into_iter().zip(&queue_times) {
                    let _ = req.resp.send(Response {
                        id: req.id,
                        output: Err(err.clone()),
                        queue_time: *q,
                        exec_time,
                        batch_requests,
                        batch_rows: rows,
                    });
                }
                if abort {
                    // ReplicaAbort: the deterministic stand-in for a lost
                    // worker thread. Every request in the batch was
                    // answered; exit (the AliveGuard flips `alive`) and
                    // let the supervisor respawn this slot.
                    return;
                }
            }
        }
        continue 'serve;
    }
}

/// Diff two cumulative metric snapshots into one controller tick's
/// [`LaneObservation`] — the controller consumes per-tick DELTAS, while
/// [`Metrics`] accumulates forever.
fn tick_observation(prev: &ModelStats, cur: &ModelStats, max_batch: usize) -> LaneObservation {
    let interval_mean = |c: &LatencyHist, p: &LatencyHist| -> f64 {
        let n = c.count().saturating_sub(p.count());
        if n == 0 {
            0.0
        } else {
            c.sum_us().saturating_sub(p.sum_us()) as f64 / n as f64
        }
    };
    let batches = cur.batches.saturating_sub(prev.batches);
    LaneObservation {
        requests: cur.requests.saturating_sub(prev.requests),
        // Load sheds only: invalid inputs are a client bug and circuit
        // sheds a backend-health problem — neither is fixed by replica
        // count, so neither may drive scaling.
        shed: (cur.shed_queue_full + cur.shed_deadline)
            .saturating_sub(prev.shed_queue_full + prev.shed_deadline),
        queue_mean_us: interval_mean(&cur.queue, &prev.queue),
        exec_mean_us: interval_mean(&cur.exec, &prev.exec),
        mean_rows: if batches == 0 {
            0.0
        } else {
            cur.batch_rows_sum.saturating_sub(prev.batch_rows_sum) as f64 / batches as f64
        },
        max_batch,
    }
}

/// Per-worker supervision bookkeeping, local to the ticker thread (the
/// shared state is the `WorkerHealth` atomics in [`Lane`]).
#[derive(Default)]
struct SupSlot {
    restarts: u32,
    /// Pending respawn deadline (exponential backoff from the restart
    /// count); `None` while the slot is healthy.
    respawn_at: Option<Instant>,
    /// Budget spent: the slot is abandoned (counted once).
    exhausted: bool,
    /// Wedged already counted for the CURRENT silence; reset when the
    /// heartbeat recovers so each wedge counts once.
    wedged_flagged: bool,
}

/// One lane's ticker context: controller and/or supervisor state plus
/// the root backend respawned replicas fork from.
struct TickerLane {
    model: String,
    lane: Arc<Lane>,
    /// The lane's registered backend. Respawns fork FRESH from it — the
    /// dead replica's backend state is suspect by definition.
    root: Arc<dyn Backend>,
    ctl: Option<Controller>,
    /// One slot per spawned worker; empty when no supervisor is
    /// configured.
    sup: Vec<SupSlot>,
}

/// The lane maintenance loop, one thread per coordinator: every tick it
/// (a) steps each lane's [`Controller`] on the metrics delta since the
/// previous tick and publishes the decision into [`LaneDynamics`]
/// (parked workers are woken on scale-up; scale-down needs no wake —
/// active workers re-check the target before every batch), and (b) runs
/// [`supervise_lane`] when a [`SupervisorConfig`] is set. All
/// convergence logic (deadband, hysteresis, bounds) lives in the pure
/// controller; all breaker logic in the pure breaker — this thread only
/// moves data and (re)spawns threads.
fn lane_ticker(
    mut lanes: Vec<TickerLane>,
    metrics: Arc<Metrics>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
) {
    let mut prev: Vec<ModelStats> = lanes.iter().map(|_| ModelStats::default()).collect();
    // Respawned worker handles live here (the originals live in
    // `Coordinator::handles`) and are joined when the ticker exits:
    // shutdown joins the ticker, the ticker joins its respawns, so
    // every thread is joined exactly once.
    let mut respawned: Vec<JoinHandle<()>> = Vec::new();
    let tick = {
        let mut t = Duration::from_millis(100);
        if let Some(c) = lanes.iter().find_map(|l| l.ctl.as_ref()) {
            t = t.min(c.config().tick);
        }
        if let Some(s) = cfg.supervisor {
            t = t.min(s.tick);
        }
        t
    };
    'tick: loop {
        // Sleep the tick in small slices so shutdown join never waits a
        // whole period.
        let mut slept = Duration::ZERO;
        while slept < tick {
            if stop.load(Ordering::Relaxed) {
                break 'tick;
            }
            let slice = (tick - slept).min(Duration::from_millis(10));
            std::thread::sleep(slice);
            slept += slice;
        }
        for (tl, prev_stats) in lanes.iter_mut().zip(prev.iter_mut()) {
            if let Some(ctl) = tl.ctl.as_mut() {
                let cur = metrics.snapshot(&tl.model).unwrap_or_default();
                let obs = tick_observation(prev_stats, &cur, cfg.max_batch);
                *prev_stats = cur;
                let was = tl.lane.dynamics.replicas();
                let d = ctl.step(&obs);
                tl.lane
                    .dynamics
                    .wait_us
                    .store(d.wait.as_micros() as u64, Ordering::Relaxed);
                tl.lane
                    .dynamics
                    .target_replicas
                    .store(d.replicas, Ordering::Relaxed);
                if d.replicas > was {
                    // Wake parked workers now instead of on their next poll.
                    tl.lane.cv.notify_all();
                }
            }
            if let Some(sup) = cfg.supervisor {
                supervise_lane(tl, &sup, &cfg, &metrics, &mut respawned);
            }
        }
    }
    for h in respawned {
        let _ = h.join();
    }
}

/// One supervision pass over a lane's worker slots: count wedged
/// replicas, respawn dead ones under the exponential-backoff restart
/// budget, abandon slots whose budget is spent.
fn supervise_lane(
    tl: &mut TickerLane,
    sup: &SupervisorConfig,
    cfg: &ServerConfig,
    metrics: &Arc<Metrics>,
    respawned: &mut Vec<JoinHandle<()>>,
) {
    // A closing lane respawns nothing: its workers exiting IS the
    // shutdown, not a failure.
    if !lock_recover(&tl.lane.state).open {
        return;
    }
    let now = Instant::now();
    let now_us = tl.lane.epoch.elapsed().as_micros() as u64;
    let timeout_us = sup.heartbeat_timeout.as_micros() as u64;
    for (idx, slot) in tl.sup.iter_mut().enumerate() {
        if slot.exhausted {
            continue;
        }
        let health = &tl.lane.health[idx];
        if health.alive.load(Ordering::SeqCst) {
            slot.respawn_at = None;
            // Alive but silent past the timeout: wedged, most likely
            // stuck inside a backend call that std threads give us no
            // safe way to interrupt. The counter is the operator signal;
            // the circuit breaker keeps traffic away from the lane.
            let age_us = now_us.saturating_sub(health.heartbeat_us.load(Ordering::SeqCst));
            if age_us > timeout_us {
                if !slot.wedged_flagged {
                    slot.wedged_flagged = true;
                    metrics.record_fault_event(&tl.model, FaultEvent::ReplicaWedged);
                }
            } else {
                slot.wedged_flagged = false;
            }
            continue;
        }
        // Dead: its AliveGuard dropped. (Parked-above-target workers are
        // alive and never reach this arm.)
        match slot.respawn_at {
            None => {
                if slot.restarts >= sup.max_restarts {
                    slot.exhausted = true;
                    metrics.record_fault_event(&tl.model, FaultEvent::RestartBudgetExhausted);
                    continue;
                }
                let shift = slot.restarts.min(16);
                let backoff = sup
                    .backoff_base
                    .saturating_mul(1u32 << shift)
                    .min(sup.backoff_cap);
                slot.respawn_at = Some(now + backoff);
            }
            Some(at) if now >= at => {
                slot.respawn_at = None;
                slot.restarts += 1;
                metrics.record_fault_event(&tl.model, FaultEvent::ReplicaRestart);
                let be = tl.root.fork_replica().unwrap_or_else(|| tl.root.clone());
                respawned.push(spawn_replica(
                    tl.lane.clone(),
                    be,
                    cfg.clone(),
                    metrics.clone(),
                    tl.model.clone(),
                    idx,
                ));
            }
            Some(_) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::InterpBackend;
    use crate::coordinator::fault::{FaultInjectingBackend, FaultKind, FaultPlan};
    use crate::figures::Figure;
    use crate::interp::Session;

    /// A backend wrapper that sleeps before executing — the test lever
    /// for keeping a replica busy while the queue fills.
    struct SlowBackend {
        inner: InterpBackend,
        delay: Duration,
    }

    impl SlowBackend {
        fn new(fig: Figure, delay_ms: u64) -> SlowBackend {
            SlowBackend {
                inner: InterpBackend::new(fig.model()).unwrap(),
                delay: Duration::from_millis(delay_ms),
            }
        }
    }

    impl Backend for SlowBackend {
        fn name(&self) -> &str {
            "slow"
        }
        fn run_batch(&self, input: &Tensor) -> Result<Tensor> {
            std::thread::sleep(self.delay);
            self.inner.run_batch(input)
        }
        fn input_spec(&self) -> Option<InputSpec> {
            self.inner.input_spec()
        }
    }

    fn config(max_batch: usize, max_wait_ms: u64, replicas: usize) -> ServerConfig {
        ServerConfig {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            replicas,
            queue_depth: 1024,
            deadline: None,
            controller: None,
            breaker: None,
            supervisor: None,
        }
    }

    fn coordinator_with(cfg: ServerConfig, backend: Arc<dyn Backend>) -> Coordinator {
        CoordinatorBuilder::new(cfg)
            .register("fig1_fc", backend)
            .start()
    }

    fn coordinator(max_batch: usize, max_wait_ms: u64) -> Coordinator {
        coordinator_with(
            config(max_batch, max_wait_ms, 1),
            Arc::new(InterpBackend::new(Figure::Fig1FcTwoMul.model()).unwrap()),
        )
    }

    #[test]
    fn single_request_round_trip() {
        let coord = coordinator(8, 1);
        let fig = Figure::Fig1FcTwoMul;
        let x = fig.input(1, 3);
        let resp = coord.infer("fig1_fc", x.clone()).unwrap();
        let out = resp.output.unwrap();
        assert_eq!(resp.batch_requests, 1);
        assert_eq!(resp.batch_rows, 1);
        // Must equal a direct session run.
        let sess = Session::new(fig.model()).unwrap();
        let want = &sess.run(&[("x", x)]).unwrap()[0];
        assert_eq!(&out, want);
        coord.shutdown();
    }

    #[test]
    fn unknown_model_rejected() {
        let coord = coordinator(8, 1);
        assert!(coord
            .submit("nope", Figure::Fig1FcTwoMul.input(1, 1))
            .is_err());
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let coord = coordinator(8, 1);
        coord.shutdown();
        assert!(coord
            .submit("fig1_fc", Figure::Fig1FcTwoMul.input(1, 1))
            .is_err());
        // Malformed submissions refuse identically (liveness is checked
        // before validation) and leave the shed counters untouched.
        let bad = Tensor::from_i8(&[1, 63], vec![0; 63]).unwrap();
        assert!(coord.submit("fig1_fc", bad).is_err());
        // No entry may even exist: nothing was executed or shed.
        let shed = coord
            .metrics
            .snapshot("fig1_fc")
            .map(|s| s.shed_total())
            .unwrap_or(0);
        assert_eq!(shed, 0);
    }

    #[test]
    fn concurrent_requests_all_answered_exactly_once_correctly() {
        let coord = Arc::new(coordinator(8, 5));
        let fig = Figure::Fig1FcTwoMul;
        let sess = Session::new(fig.model()).unwrap();
        let n_threads = 4;
        let per_thread = 16;

        let mut joins = Vec::new();
        for t in 0..n_threads {
            let coord = coord.clone();
            joins.push(std::thread::spawn(move || {
                let fig = Figure::Fig1FcTwoMul;
                let mut results = Vec::new();
                for i in 0..per_thread {
                    let seed = (t * 1000 + i) as u64;
                    let x = fig.input(1, seed);
                    let resp = coord.infer("fig1_fc", x.clone()).unwrap();
                    results.push((seed, x, resp));
                }
                results
            }));
        }
        let mut total = 0;
        let mut batched_over_1 = 0;
        for j in joins {
            for (seed, x, resp) in j.join().unwrap() {
                let want = &sess.run(&[("x", x)]).unwrap()[0];
                let got = resp
                    .output
                    .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
                assert_eq!(&got, want, "seed {seed}");
                // Single-row clients: requests == rows, both within cap.
                assert!(resp.batch_requests >= 1 && resp.batch_requests <= 8);
                assert_eq!(resp.batch_rows, resp.batch_requests);
                if resp.batch_requests > 1 {
                    batched_over_1 += 1;
                }
                total += 1;
            }
        }
        assert_eq!(total, n_threads * per_thread);
        // With 4 concurrent submitters and 5ms windows, at least some
        // requests must actually have been fused.
        assert!(batched_over_1 > 0, "dynamic batching never engaged");
        let stats = coord.metrics.snapshot("fig1_fc").unwrap();
        assert_eq!(stats.requests, (n_threads * per_thread) as u64);
        assert!(stats.mean_batch() > 1.0);
        assert_eq!(stats.shed_total(), 0);
        coord.shutdown();
    }

    #[test]
    fn replica_pool_answers_everything_correctly() {
        // 4 replicas over one shared plan; correctness must be identical
        // to the single-worker lane.
        let coord = Arc::new(coordinator_with(
            config(4, 1, 4),
            Arc::new(InterpBackend::new(Figure::Fig1FcTwoMul.model()).unwrap()),
        ));
        let sess = Session::new(Figure::Fig1FcTwoMul.model()).unwrap();
        let mut joins = Vec::new();
        for t in 0..8u64 {
            let coord = coord.clone();
            joins.push(std::thread::spawn(move || {
                let fig = Figure::Fig1FcTwoMul;
                (0..12u64)
                    .map(|i| {
                        let seed = t * 100 + i;
                        let x = fig.input(1, seed);
                        (x.clone(), coord.infer("fig1_fc", x).unwrap())
                    })
                    .collect::<Vec<_>>()
            }));
        }
        let mut total = 0;
        for j in joins {
            for (x, resp) in j.join().unwrap() {
                let want = &sess.run(&[("x", x)]).unwrap()[0];
                assert_eq!(&resp.output.unwrap(), want);
                total += 1;
            }
        }
        assert_eq!(total, 8 * 12);
        assert_eq!(
            coord.metrics.snapshot("fig1_fc").unwrap().requests,
            8 * 12
        );
        coord.shutdown();
    }

    #[test]
    fn multi_row_requests_never_overshoot_max_batch() {
        // Regression for the overshoot bug: the old batcher checked
        // `rows < max_batch` BEFORE adding a request's rows, so two 3-row
        // requests fused into a 6-row batch under max_batch = 4.
        let fig = Figure::Fig1FcTwoMul;
        let sess = Session::new(fig.model()).unwrap();
        let coord = coordinator_with(
            config(4, 25, 1),
            Arc::new(SlowBackend::new(fig, 30)),
        );
        // r1 occupies the replica; r2 + r3 queue up and MUST NOT fuse
        // (3 + 3 > 4), even though both sit queued together.
        let x1 = fig.input(3, 1);
        let x2 = fig.input(3, 2);
        let x3 = fig.input(3, 3);
        let rx1 = coord.submit("fig1_fc", x1.clone()).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        let rx2 = coord.submit("fig1_fc", x2.clone()).unwrap();
        let rx3 = coord.submit("fig1_fc", x3.clone()).unwrap();
        for (rx, x) in [(rx1, x1), (rx2, x2), (rx3, x3)] {
            let resp = rx.recv().unwrap();
            assert!(
                resp.batch_rows <= 4,
                "fused {} rows past max_batch 4",
                resp.batch_rows
            );
            assert_eq!(resp.batch_requests, 1, "3-row requests must not fuse");
            assert_eq!(resp.batch_rows, 3);
            let want = &sess.run(&[("x", x)]).unwrap()[0];
            assert_eq!(&resp.output.unwrap(), want);
        }
        coord.shutdown();
    }

    #[test]
    fn oversized_single_request_runs_alone() {
        // A request larger than max_batch cannot be split; it runs alone.
        let fig = Figure::Fig1FcTwoMul;
        let sess = Session::new(fig.model()).unwrap();
        let coord = coordinator(4, 1);
        let x = fig.input(9, 77);
        let resp = coord.infer("fig1_fc", x.clone()).unwrap();
        assert_eq!(resp.batch_requests, 1);
        assert_eq!(resp.batch_rows, 9);
        let want = &sess.run(&[("x", x)]).unwrap()[0];
        assert_eq!(&resp.output.unwrap(), want);
        coord.shutdown();
    }

    #[test]
    fn malformed_request_rejected_alone_good_ones_answered() {
        // Regression for the poison-batch bug: a bad tensor used to fail
        // concat (or the backend) for every co-batched request. Now it is
        // rejected at admission, alone.
        let fig = Figure::Fig1FcTwoMul;
        let sess = Session::new(fig.model()).unwrap();
        let coord = coordinator_with(
            config(8, 20, 1),
            Arc::new(SlowBackend::new(fig, 20)),
        );
        // Occupy the replica so good + bad would have co-batched.
        let occupier = coord.submit("fig1_fc", fig.input(1, 9)).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let good1 = fig.input(1, 10);
        let good2 = fig.input(1, 11);
        let rx_good1 = coord.submit("fig1_fc", good1.clone()).unwrap();
        // Wrong feature dim (63 instead of 64).
        let bad = Tensor::from_i8(&[1, 63], vec![0; 63]).unwrap();
        let rx_bad = coord.submit("fig1_fc", bad).unwrap();
        let rx_good2 = coord.submit("fig1_fc", good2.clone()).unwrap();

        // The bad request is shed immediately with a typed reason...
        let resp = rx_bad
            .recv_timeout(Duration::from_millis(100))
            .expect("rejection must not wait for a batch");
        match resp.reject_reason() {
            Some(RejectReason::InvalidInput(msg)) => {
                assert!(msg.contains("axis 1"), "unexpected message: {msg}")
            }
            other => panic!("expected InvalidInput, got {other:?}"),
        }
        // ...and every good request is answered correctly.
        for (rx, x) in [(rx_good1, good1), (rx_good2, good2)] {
            let resp = rx.recv().unwrap();
            let want = &sess.run(&[("x", x)]).unwrap()[0];
            assert_eq!(&resp.output.unwrap(), want);
        }
        occupier.recv().unwrap().output.unwrap();
        let stats = coord.metrics.snapshot("fig1_fc").unwrap();
        assert_eq!(stats.shed_invalid, 1);
        assert_eq!(stats.errors, 0, "no fused batch may have errored");
        coord.shutdown();
    }

    #[test]
    fn wrong_dtype_rejected_with_typed_reason() {
        let coord = coordinator(8, 1);
        let bad = Tensor::from_f32(&[1, 64], vec![0.0; 64]).unwrap();
        let resp = coord
            .submit("fig1_fc", bad)
            .unwrap()
            .recv()
            .unwrap();
        assert!(matches!(
            resp.reject_reason(),
            Some(RejectReason::InvalidInput(_))
        ));
        coord.shutdown();
    }

    #[test]
    fn queue_full_sheds_immediately() {
        let fig = Figure::Fig1FcTwoMul;
        let mut cfg = config(1, 1, 1);
        cfg.queue_depth = 2;
        let coord = coordinator_with(cfg, Arc::new(SlowBackend::new(fig, 200)));
        // First request occupies the replica (60ms > the worker's 50ms
        // poll interval, so pickup is certain even if a wakeup is lost)...
        let _busy = coord.submit("fig1_fc", fig.input(1, 1)).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        // ...two fill the queue to its cap...
        let _q1 = coord.submit("fig1_fc", fig.input(1, 2)).unwrap();
        let _q2 = coord.submit("fig1_fc", fig.input(1, 3)).unwrap();
        // ...and the next is shed instantly, not queued unboundedly.
        let t0 = Instant::now();
        let resp = coord
            .submit("fig1_fc", fig.input(1, 4))
            .unwrap()
            .recv_timeout(Duration::from_millis(100))
            .expect("shed must be immediate");
        assert!(matches!(
            resp.reject_reason(),
            Some(RejectReason::QueueFull)
        ));
        assert!(t0.elapsed() < Duration::from_millis(100));
        assert_eq!(
            coord.metrics.snapshot("fig1_fc").unwrap().shed_queue_full,
            1
        );
        coord.shutdown_now();
    }

    #[test]
    fn submit_purges_expired_queue_entries_before_depth_check() {
        // A queue full of already-dead requests must not shed live
        // submits as QueueFull: submit sweeps expired entries from the
        // front (answering them DeadlineExceeded) before judging depth.
        let fig = Figure::Fig1FcTwoMul;
        let mut cfg = config(1, 1, 1);
        cfg.queue_depth = 2;
        cfg.deadline = Some(Duration::from_millis(30));
        let coord = coordinator_with(cfg, Arc::new(SlowBackend::new(fig, 300)));
        let _busy = coord.submit("fig1_fc", fig.input(1, 1)).unwrap();
        std::thread::sleep(Duration::from_millis(60)); // replica is busy now
        // Fill the queue to its cap; both entries die 30ms later.
        let rx_d1 = coord.submit("fig1_fc", fig.input(1, 2)).unwrap();
        let rx_d2 = coord.submit("fig1_fc", fig.input(1, 3)).unwrap();
        std::thread::sleep(Duration::from_millis(40)); // both expired
        // The next submit purges the dead fronts and is ACCEPTED.
        let _rx_live = coord.submit("fig1_fc", fig.input(1, 4)).unwrap();
        for rx in [rx_d1, rx_d2] {
            let resp = rx
                .recv_timeout(Duration::from_millis(100))
                .expect("dead entries are answered at submit-time purge");
            assert!(matches!(
                resp.reject_reason(),
                Some(RejectReason::DeadlineExceeded)
            ));
        }
        let stats = coord.metrics.snapshot("fig1_fc").unwrap();
        assert_eq!(stats.shed_queue_full, 0, "live submit misattributed");
        assert_eq!(stats.shed_deadline, 2);
        coord.shutdown_now();
    }

    #[test]
    fn deadline_exceeded_requests_are_shed() {
        let fig = Figure::Fig1FcTwoMul;
        let mut cfg = config(1, 1, 1);
        cfg.deadline = Some(Duration::from_millis(40));
        let coord = coordinator_with(cfg, Arc::new(SlowBackend::new(fig, 120)));
        let rx_a = coord.submit("fig1_fc", fig.input(1, 1)).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        // Queued behind a 120ms execution with a 40ms deadline: shed.
        let rx_b = coord.submit("fig1_fc", fig.input(1, 2)).unwrap();
        let resp_b = rx_b.recv().unwrap();
        assert!(matches!(
            resp_b.reject_reason(),
            Some(RejectReason::DeadlineExceeded)
        ));
        assert!(resp_b.queue_time >= Duration::from_millis(40));
        // The in-flight request still completes normally.
        rx_a.recv().unwrap().output.unwrap();
        assert_eq!(coord.metrics.snapshot("fig1_fc").unwrap().shed_deadline, 1);
        coord.shutdown();
    }

    #[test]
    fn shutdown_drains_every_accepted_request() {
        let fig = Figure::Fig1FcTwoMul;
        let sess = Session::new(fig.model()).unwrap();
        let coord = coordinator_with(
            config(1, 1, 1),
            Arc::new(SlowBackend::new(fig, 10)),
        );
        let mut pending = Vec::new();
        for i in 0..8u64 {
            let x = fig.input(1, i);
            pending.push((x.clone(), coord.submit("fig1_fc", x).unwrap()));
        }
        // Graceful shutdown: blocks until the queue is drained...
        coord.shutdown();
        // ...so every accepted request has a REAL response waiting.
        for (x, rx) in pending {
            let resp = rx.try_recv().expect("response must exist post-drain");
            let want = &sess.run(&[("x", x)]).unwrap()[0];
            assert_eq!(&resp.output.unwrap(), want);
        }
        assert_eq!(coord.metrics.snapshot("fig1_fc").unwrap().requests, 8);
    }

    #[test]
    fn shutdown_now_drops_queued_requests() {
        let fig = Figure::Fig1FcTwoMul;
        let coord = coordinator_with(
            config(1, 1, 1),
            Arc::new(SlowBackend::new(fig, 100)),
        );
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            rxs.push(coord.submit("fig1_fc", fig.input(1, i)).unwrap());
        }
        std::thread::sleep(Duration::from_millis(20));
        let t0 = Instant::now();
        coord.shutdown_now();
        // Hard stop returns without draining ~500ms of queued work.
        assert!(t0.elapsed() < Duration::from_millis(450));
        let mut answered = 0;
        let mut dropped = 0;
        for rx in rxs {
            match rx.recv_timeout(Duration::from_millis(200)) {
                Ok(resp) => {
                    resp.output.unwrap();
                    answered += 1;
                }
                Err(_) => dropped += 1,
            }
        }
        assert_eq!(answered + dropped, 6);
        assert!(dropped >= 1, "hard stop must drop queued requests");
    }

    #[test]
    fn batch_rows_and_requests_diverge_for_multi_row_submissions() {
        let fig = Figure::Fig1FcTwoMul;
        let coord = coordinator(8, 1);
        let resp = coord.infer("fig1_fc", fig.input(4, 5)).unwrap();
        resp.output.unwrap();
        assert_eq!(resp.batch_requests, 1);
        assert_eq!(resp.batch_rows, 4);
        let stats = coord.metrics.snapshot("fig1_fc").unwrap();
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.mean_batch(), 1.0);
        assert_eq!(stats.mean_rows(), 4.0);
        coord.shutdown();
    }

    #[test]
    fn batch_transparency_property() {
        // Property: for ANY request interleaving, ANY replica count, and
        // ANY mix of well-formed and malformed submissions, serving is
        // transparent — well-formed outputs are bit-identical to direct
        // Session runs, malformed ones get a typed rejection, and every
        // submission receives EXACTLY one response.
        use crate::proptest_util::{run_prop, Gen};
        struct Plan;
        impl Gen for Plan {
            /// (seed, rows) per request; rows == 0 encodes a malformed
            /// submission (wrong feature dim).
            type Value = Vec<(u64, usize)>;
            fn generate(&self, rng: &mut crate::train::Rng) -> Vec<(u64, usize)> {
                let n = 1 + rng.below(12);
                (0..n)
                    .map(|_| {
                        let seed = rng.next_u64() % 1000;
                        let rows = rng.below(4); // 0 => malformed
                        (seed, rows)
                    })
                    .collect()
            }
            fn shrink(&self, v: &Vec<(u64, usize)>) -> Vec<Vec<(u64, usize)>> {
                if v.len() > 1 {
                    vec![v[..v.len() / 2].to_vec()]
                } else {
                    Vec::new()
                }
            }
        }
        let fig = Figure::Fig1FcTwoMul;
        let sess = Session::new(fig.model()).unwrap();
        for replicas in [1usize, 3] {
            let coord = coordinator_with(
                config(4, 1, replicas),
                Arc::new(InterpBackend::new(fig.model()).unwrap()),
            );
            run_prop(
                &format!("batch_transparency_r{replicas}"),
                &Plan,
                7 + replicas as u64,
                20,
                |reqs| {
                    let rxs: Vec<_> = reqs
                        .iter()
                        .map(|&(s, rows)| {
                            let x = if rows == 0 {
                                // Malformed: wrong feature dim.
                                Tensor::from_i8(&[1, 63], vec![0; 63]).unwrap()
                            } else {
                                fig.input(rows, s)
                            };
                            coord.submit("fig1_fc", x).unwrap()
                        })
                        .collect();
                    for (&(s, rows), rx) in reqs.iter().zip(rxs) {
                        let resp = rx.recv().map_err(|e| e.to_string())?;
                        if rows == 0 {
                            match resp.reject_reason() {
                                Some(RejectReason::InvalidInput(_)) => {}
                                other => {
                                    return Err(format!(
                                        "malformed request: expected InvalidInput, got {other:?}"
                                    ))
                                }
                            }
                            continue;
                        }
                        let got = resp.output.map_err(|e| e.to_string())?;
                        let want =
                            &sess.run(&[("x", fig.input(rows, s))]).unwrap()[0];
                        if &got != want {
                            return Err(format!(
                                "mismatch for seed {s} ({rows} rows, {replicas} replicas)"
                            ));
                        }
                        // Exactly-once: a second receive must find the
                        // channel empty (sender consumed by the send).
                        if rx.try_recv().is_ok() {
                            return Err(format!("seed {s}: more than one response"));
                        }
                    }
                    Ok(())
                },
            );
            coord.shutdown();
        }
    }

    #[test]
    fn tick_observation_diffs_cumulative_snapshots() {
        let m = Metrics::default();
        m.record_batch(
            "lane",
            4,
            8,
            &[Duration::from_micros(100); 4],
            Duration::from_micros(400),
            BatchFate::Success,
        );
        let first = m.snapshot("lane").unwrap();
        let obs = tick_observation(&ModelStats::default(), &first, 8);
        assert_eq!(obs.requests, 4);
        assert_eq!(obs.shed, 0);
        assert_eq!(obs.mean_rows, 8.0);
        assert_eq!(obs.queue_mean_us, 100.0);
        assert_eq!(obs.exec_mean_us, 400.0);
        // Second interval: one 2-row batch, one load shed, one invalid
        // (which must NOT count — no replica count fixes a client bug).
        m.record_batch(
            "lane",
            2,
            2,
            &[Duration::from_micros(300); 2],
            Duration::from_micros(600),
            BatchFate::Success,
        );
        m.record_shed("lane", ShedKind::QueueFull);
        m.record_shed("lane", ShedKind::InvalidInput);
        let second = m.snapshot("lane").unwrap();
        let obs = tick_observation(&first, &second, 8);
        assert_eq!(obs.requests, 2);
        assert_eq!(obs.shed, 1);
        assert_eq!(obs.mean_rows, 2.0);
        assert_eq!(obs.queue_mean_us, 300.0);
        assert_eq!(obs.exec_mean_us, 600.0);
        // An idle interval is all zeros — the controller's hold state.
        let obs = tick_observation(&second, &second, 8);
        let idle = LaneObservation {
            max_batch: 8,
            ..LaneObservation::default()
        };
        assert_eq!(obs, idle);
    }

    #[test]
    fn lane_targets_stay_fixed_without_a_controller() {
        let fig = Figure::Fig1FcTwoMul;
        let coord = coordinator(8, 2);
        assert_eq!(
            coord.lane_targets("fig1_fc"),
            Some((1, Duration::from_millis(2)))
        );
        coord.infer("fig1_fc", fig.input(1, 1)).unwrap().output.unwrap();
        assert_eq!(
            coord.lane_targets("fig1_fc"),
            Some((1, Duration::from_millis(2))),
            "no controller may rewrite the launch targets"
        );
        assert_eq!(coord.lane_targets("nope"), None);
        coord.shutdown();
    }

    #[test]
    fn controller_scales_replicas_up_under_sustained_backlog() {
        let fig = Figure::Fig1FcTwoMul;
        let mut cfg = config(1, 1, 1);
        cfg.controller = Some(ControllerConfig {
            min_replicas: 1,
            max_replicas: 3,
            min_wait: Duration::from_micros(200),
            max_wait: Duration::from_millis(4),
            dwell_ticks: 2,
            tick: Duration::from_millis(20),
            ..ControllerConfig::default()
        });
        let coord = Arc::new(coordinator_with(cfg, Arc::new(SlowBackend::new(fig, 5))));
        // Launch targets: the configured count clamped into bounds.
        let (r0, w0) = coord.lane_targets("fig1_fc").unwrap();
        assert_eq!(r0, 1);
        assert_eq!(w0, Duration::from_millis(1), "launch window is max_wait");
        // Offered load far beyond one 5ms-per-request replica: queue wait
        // dominates exec time, so the controller must add replicas —
        // waking workers that were spawned parked.
        let stop = Arc::new(AtomicBool::new(false));
        let mut feeders = Vec::new();
        for t in 0..4u64 {
            let coord = coord.clone();
            let stop = stop.clone();
            feeders.push(std::thread::spawn(move || {
                let fig = Figure::Fig1FcTwoMul;
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let x = fig.input(1, t * 100_000 + i);
                    if let Ok(rx) = coord.submit("fig1_fc", x) {
                        let _ = rx.recv();
                    }
                    i += 1;
                }
            }));
        }
        let t0 = Instant::now();
        let mut peak = 1usize;
        while t0.elapsed() < Duration::from_secs(5) {
            let (r, _) = coord.lane_targets("fig1_fc").unwrap();
            assert!(r <= 3, "replica target exceeded the controller bound");
            peak = peak.max(r);
            if peak > 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        stop.store(true, Ordering::Relaxed);
        for f in feeders {
            f.join().unwrap();
        }
        assert!(peak > 1, "sustained backlog never scaled the lane up");
        // Everything submitted was answered correctly throughout the
        // scale-up (receivers in the feeder loops asserted delivery);
        // spot-check correctness after it.
        let sess = Session::new(fig.model()).unwrap();
        let x = fig.input(1, 424242);
        let resp = coord.infer("fig1_fc", x.clone()).unwrap();
        let want = &sess.run(&[("x", x)]).unwrap()[0];
        assert_eq!(&resp.output.unwrap(), want);
        coord.shutdown();
    }

    #[test]
    fn backend_panic_is_isolated_and_typed() {
        let fig = Figure::Fig1FcTwoMul;
        let inner = Arc::new(InterpBackend::new(fig.model()).unwrap());
        let coord = coordinator_with(
            config(8, 1, 1),
            Arc::new(FaultInjectingBackend::new(
                inner,
                FaultPlan::none().at(0, FaultKind::Panic),
            )),
        );
        // Call 0 panics: the request gets a typed BackendPanic...
        let resp = coord.infer("fig1_fc", fig.input(1, 1)).unwrap();
        match resp.output {
            Err(ServeError::BackendPanic(msg)) => {
                assert!(msg.contains("injected panic at call 0"), "msg: {msg}")
            }
            other => panic!("expected BackendPanic, got {other:?}"),
        }
        // ...and the SAME worker keeps serving: call 1 is clean.
        let sess = Session::new(fig.model()).unwrap();
        let x = fig.input(1, 2);
        let resp = coord.infer("fig1_fc", x.clone()).unwrap();
        let want = &sess.run(&[("x", x)]).unwrap()[0];
        assert_eq!(&resp.output.unwrap(), want);
        let stats = coord.metrics.snapshot("fig1_fc").unwrap();
        assert_eq!(stats.panics, 1);
        assert_eq!(stats.errors, 0);
        coord.shutdown();
    }

    #[test]
    fn panic_never_poisons_submit_or_siblings() {
        // Regression for the pre-fault cascade: one panicking replica
        // used to unwind through the worker, poisoning the lane and
        // metrics mutexes, which turned every later submit() and every
        // sibling replica into a `lock().unwrap()` panic of its own.
        let fig = Figure::Fig1FcTwoMul;
        let inner = Arc::new(InterpBackend::new(fig.model()).unwrap());
        let coord = coordinator_with(
            config(1, 1, 3),
            Arc::new(FaultInjectingBackend::new(
                inner,
                FaultPlan::none()
                    .at(0, FaultKind::Panic)
                    .at(1, FaultKind::Panic),
            )),
        );
        let sess = Session::new(fig.model()).unwrap();
        let mut panics = 0;
        let mut oks = 0;
        // Sequential infers: call index == request index, so exactly the
        // two pinned calls panic, and every request AFTER a panic proves
        // submit() and the (shared-lane) sibling replicas still work.
        for i in 0..24u64 {
            let x = fig.input(1, i);
            let resp = coord.infer("fig1_fc", x.clone()).unwrap();
            match resp.output {
                Ok(out) => {
                    let want = &sess.run(&[("x", x)]).unwrap()[0];
                    assert_eq!(&out, want);
                    oks += 1;
                }
                Err(ServeError::BackendPanic(_)) => panics += 1,
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!(panics, 2, "exactly the two pinned calls panic");
        assert_eq!(oks, 22);
        // Metrics survived the panics and account for every request.
        let stats = coord.metrics.snapshot("fig1_fc").unwrap();
        assert_eq!(stats.requests, 24);
        assert_eq!(stats.panics, 2);
        coord.shutdown();
    }

    #[test]
    fn worker_lost_is_typed_on_infer() {
        let fig = Figure::Fig1FcTwoMul;
        let coord = Arc::new(coordinator_with(
            config(1, 1, 1),
            Arc::new(SlowBackend::new(fig, 150)),
        ));
        // Occupy the replica, then park a second request in the queue.
        let _busy = coord.submit("fig1_fc", fig.input(1, 1)).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        let waiter = {
            let coord = coord.clone();
            std::thread::spawn(move || {
                let fig = Figure::Fig1FcTwoMul;
                coord.infer("fig1_fc", fig.input(1, 2)).unwrap()
            })
        };
        std::thread::sleep(Duration::from_millis(30));
        // The hard stop drops the queued request — its response sender
        // is gone. infer must surface that as a typed WorkerLost
        // response, not a bare channel error.
        coord.shutdown_now();
        let resp = waiter.join().unwrap();
        assert_eq!(resp.output, Err(ServeError::WorkerLost));
        assert_eq!(resp.batch_requests, 0);
    }

    #[test]
    fn circuit_breaker_opens_and_sheds_fast() {
        let fig = Figure::Fig1FcTwoMul;
        let inner = Arc::new(InterpBackend::new(fig.model()).unwrap());
        let mut cfg = config(8, 1, 1);
        cfg.breaker = Some(BreakerConfig {
            failures_to_open: 2,
            cooldown: Duration::from_secs(30),
            half_open_probes: 1,
        });
        let coord = coordinator_with(
            cfg,
            Arc::new(FaultInjectingBackend::new(
                inner,
                // Every call fails: the lane is genuinely sick.
                FaultPlan::seeded(1, 1000, &[FaultKind::Error]),
            )),
        );
        // Two consecutive failed batches trip the breaker...
        for i in 0..2u64 {
            let resp = coord.infer("fig1_fc", fig.input(1, i)).unwrap();
            assert!(matches!(resp.output, Err(ServeError::Exec(_))));
        }
        // ...after which submissions shed instantly, without queueing
        // into the sick lane.
        let t0 = Instant::now();
        let resp = coord
            .submit("fig1_fc", fig.input(1, 9))
            .unwrap()
            .recv_timeout(Duration::from_millis(100))
            .expect("circuit shed must be immediate");
        assert!(matches!(
            resp.reject_reason(),
            Some(RejectReason::CircuitOpen)
        ));
        assert!(t0.elapsed() < Duration::from_millis(100));
        let stats = coord.metrics.snapshot("fig1_fc").unwrap();
        assert_eq!(stats.breaker_opens, 1);
        assert!(stats.shed_circuit >= 1);
        // Malformed inputs keep their InvalidInput classification even
        // while the breaker is open (validation precedes admission).
        let bad = Tensor::from_i8(&[1, 63], vec![0; 63]).unwrap();
        let resp = coord.submit("fig1_fc", bad).unwrap().recv().unwrap();
        assert!(matches!(
            resp.reject_reason(),
            Some(RejectReason::InvalidInput(_))
        ));
        coord.shutdown();
    }

    #[test]
    fn batch_transparency_property_under_faults() {
        // The transparency property must survive an adversarial backend:
        // with errors, panics, and delays injected at seeded schedule
        // points, every submission still gets EXACTLY one response,
        // malformed inputs keep their typed rejection, surviving outputs
        // stay bit-identical to direct Session runs, and every failure
        // is a typed Exec/BackendPanic — never a hang, a missing
        // response, or a poisoned coordinator.
        use crate::proptest_util::{run_prop, Gen};
        struct Plan;
        impl Gen for Plan {
            /// (seed, rows) per request; rows == 0 encodes a malformed
            /// submission (wrong feature dim).
            type Value = Vec<(u64, usize)>;
            fn generate(&self, rng: &mut crate::train::Rng) -> Vec<(u64, usize)> {
                let n = 1 + rng.below(12);
                (0..n)
                    .map(|_| (rng.next_u64() % 1000, rng.below(4)))
                    .collect()
            }
            fn shrink(&self, v: &Vec<(u64, usize)>) -> Vec<Vec<(u64, usize)>> {
                if v.len() > 1 {
                    vec![v[..v.len() / 2].to_vec()]
                } else {
                    Vec::new()
                }
            }
        }
        let fig = Figure::Fig1FcTwoMul;
        let sess = Session::new(fig.model()).unwrap();
        for replicas in [1usize, 3] {
            let inner = Arc::new(InterpBackend::new(fig.model()).unwrap());
            // ~20% of calls fault, split across all three kinds.
            let plan = FaultPlan::seeded(
                0xC4A05 + replicas as u64,
                200,
                &[FaultKind::Error, FaultKind::Panic, FaultKind::Delay],
            );
            let coord = coordinator_with(
                config(4, 1, replicas),
                Arc::new(FaultInjectingBackend::new(inner, plan)),
            );
            run_prop(
                &format!("transparency_under_faults_r{replicas}"),
                &Plan,
                11 + replicas as u64,
                15,
                |reqs| {
                    let rxs: Vec<_> = reqs
                        .iter()
                        .map(|&(s, rows)| {
                            let x = if rows == 0 {
                                Tensor::from_i8(&[1, 63], vec![0; 63]).unwrap()
                            } else {
                                fig.input(rows, s)
                            };
                            coord.submit("fig1_fc", x).unwrap()
                        })
                        .collect();
                    for (&(s, rows), rx) in reqs.iter().zip(rxs) {
                        let resp = rx
                            .recv_timeout(Duration::from_secs(10))
                            .map_err(|e| format!("seed {s}: no response ({e})"))?;
                        if rows == 0 {
                            // Malformed inputs are classified BEFORE any
                            // fault can touch them.
                            match resp.reject_reason() {
                                Some(RejectReason::InvalidInput(_)) => {}
                                other => {
                                    return Err(format!(
                                        "malformed: expected InvalidInput, got {other:?}"
                                    ))
                                }
                            }
                        } else {
                            match resp.output {
                                Ok(got) => {
                                    let want =
                                        &sess.run(&[("x", fig.input(rows, s))]).unwrap()[0];
                                    if &got != want {
                                        return Err(format!("seed {s}: output mismatch"));
                                    }
                                }
                                Err(ServeError::Exec(ref m)) if m.contains("injected") => {}
                                Err(ServeError::BackendPanic(_)) => {}
                                Err(ref e) => {
                                    return Err(format!("seed {s}: unexpected fate {e}"))
                                }
                            }
                        }
                        if rx.try_recv().is_ok() {
                            return Err(format!("seed {s}: more than one response"));
                        }
                    }
                    Ok(())
                },
            );
            // The chaos never breaks the graceful-drain contract.
            coord.shutdown();
        }
    }
}
