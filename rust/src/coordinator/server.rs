//! The serving loop: per-model worker threads with dynamic batching.
//!
//! Size + deadline policy: a worker takes the first queued request,
//! then keeps admitting requests until either `max_batch` is reached or
//! `max_wait` has elapsed since the batch opened; the batch is fused
//! along axis 0 (the models' symbolic `N`), executed once, and split
//! back per request.

use super::backend::{concat_batch, split_batch, Backend};
use super::metrics::Metrics;
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serving configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Maximum requests fused into one execution.
    pub max_batch: usize,
    /// Maximum time a batch stays open waiting for more requests.
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// A completed inference.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub output: Result<Tensor, String>,
    /// Time spent queued before execution started.
    pub queue_time: Duration,
    /// Execution wall time of the fused batch.
    pub exec_time: Duration,
    /// Size of the batch this request was fused into.
    pub batch_size: usize,
}

struct Request {
    id: u64,
    input: Tensor,
    enqueued: Instant,
    resp: mpsc::Sender<Response>,
}

struct ModelLane {
    tx: mpsc::Sender<Request>,
}

/// The coordinator: routes requests to per-model batching workers.
pub struct Coordinator {
    lanes: HashMap<String, ModelLane>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    shutdown: Arc<AtomicBool>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

/// Builder registering (model name -> backend) lanes.
pub struct CoordinatorBuilder {
    config: ServerConfig,
    backends: Vec<(String, Arc<dyn Backend>)>,
}

impl CoordinatorBuilder {
    pub fn new(config: ServerConfig) -> CoordinatorBuilder {
        CoordinatorBuilder {
            config,
            backends: Vec::new(),
        }
    }

    /// Register a backend to serve `model`.
    pub fn register(mut self, model: &str, backend: Arc<dyn Backend>) -> Self {
        self.backends.push((model.to_string(), backend));
        self
    }

    /// Spawn the workers and return the running coordinator.
    pub fn start(self) -> Coordinator {
        let metrics = Arc::new(Metrics::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut lanes = HashMap::new();
        let mut handles = Vec::new();
        for (model, backend) in self.backends {
            let (tx, rx) = mpsc::channel::<Request>();
            let cfg = self.config.clone();
            let m = metrics.clone();
            let stop = shutdown.clone();
            let model_name = model.clone();
            let handle = std::thread::Builder::new()
                .name(format!("lane-{model}"))
                .spawn(move || batch_worker(rx, backend, cfg, m, stop, model_name))
                .expect("spawning lane worker");
            lanes.insert(model, ModelLane { tx });
            handles.push(handle);
        }
        Coordinator {
            lanes,
            metrics,
            next_id: AtomicU64::new(1),
            shutdown,
            handles: Mutex::new(handles),
        }
    }
}

impl Coordinator {
    /// Submit one request; returns a receiver for its response.
    pub fn submit(&self, model: &str, input: Tensor) -> Result<mpsc::Receiver<Response>> {
        let lane = self
            .lanes
            .get(model)
            .ok_or_else(|| anyhow!("unknown model '{model}'"))?;
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            input,
            enqueued: Instant::now(),
            resp: tx,
        };
        lane.tx
            .send(req)
            .map_err(|_| anyhow!("lane for '{model}' is down"))?;
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, model: &str, input: Tensor) -> Result<Response> {
        let rx = self.submit(model, input)?;
        rx.recv().map_err(|_| anyhow!("response channel closed"))
    }

    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.lanes.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// Stop all workers (drains nothing; pending requests get channel
    /// errors, matching a hard shutdown).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn batch_worker(
    rx: mpsc::Receiver<Request>,
    backend: Arc<dyn Backend>,
    cfg: ServerConfig,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    model: String,
) {
    loop {
        // Wait for the batch-opening request.
        let first = match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        let opened = Instant::now();
        let mut batch = vec![first];
        let mut rows = batch[0].input.shape().first().copied().unwrap_or(1);
        // Admit until size or deadline; requests are whole tensors whose
        // row counts add up (clients usually send single rows).
        while rows < cfg.max_batch {
            let elapsed = opened.elapsed();
            if elapsed >= cfg.max_wait {
                break;
            }
            match rx.recv_timeout(cfg.max_wait - elapsed) {
                Ok(r) => {
                    rows += r.input.shape().first().copied().unwrap_or(1);
                    batch.push(r);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        let exec_start = Instant::now();
        let queue_times: Vec<Duration> = batch
            .iter()
            .map(|r| exec_start.duration_since(r.enqueued))
            .collect();
        let inputs: Vec<Tensor> = batch.iter().map(|r| r.input.clone()).collect();
        let sizes: Vec<usize> = inputs
            .iter()
            .map(|t| t.shape().first().copied().unwrap_or(1))
            .collect();

        let result = concat_batch(&inputs).and_then(|fused| {
            let out = backend.run_batch(&fused)?;
            split_batch(&out, &sizes)
        });
        let exec_time = exec_start.elapsed();

        match result {
            Ok(outputs) => {
                metrics.record_batch(&model, batch.len(), &queue_times, exec_time, false);
                for ((req, out), q) in batch.into_iter().zip(outputs).zip(&queue_times) {
                    let _ = req.resp.send(Response {
                        id: req.id,
                        output: Ok(out),
                        queue_time: *q,
                        exec_time,
                        batch_size: rows,
                    });
                }
            }
            Err(e) => {
                metrics.record_batch(&model, batch.len(), &queue_times, exec_time, true);
                let msg = e.to_string();
                for (req, q) in batch.into_iter().zip(&queue_times) {
                    let _ = req.resp.send(Response {
                        id: req.id,
                        output: Err(msg.clone()),
                        queue_time: *q,
                        exec_time,
                        batch_size: rows,
                    });
                }
            }
        }
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::InterpBackend;
    use crate::figures::Figure;
    use crate::interp::Session;

    fn coordinator(max_batch: usize, max_wait_ms: u64) -> Coordinator {
        let fig = Figure::Fig1FcTwoMul;
        CoordinatorBuilder::new(ServerConfig {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
        })
        .register(
            "fig1_fc",
            Arc::new(InterpBackend::new(fig.model()).unwrap()),
        )
        .start()
    }

    #[test]
    fn single_request_round_trip() {
        let coord = coordinator(8, 1);
        let fig = Figure::Fig1FcTwoMul;
        let x = fig.input(1, 3);
        let resp = coord.infer("fig1_fc", x.clone()).unwrap();
        let out = resp.output.unwrap();
        // Must equal a direct session run.
        let sess = Session::new(fig.model()).unwrap();
        let want = &sess.run(&[("x", x)]).unwrap()[0];
        assert_eq!(&out, want);
        coord.shutdown();
    }

    #[test]
    fn unknown_model_rejected() {
        let coord = coordinator(8, 1);
        assert!(coord
            .submit("nope", Figure::Fig1FcTwoMul.input(1, 1))
            .is_err());
    }

    #[test]
    fn concurrent_requests_all_answered_exactly_once_correctly() {
        let coord = Arc::new(coordinator(8, 5));
        let fig = Figure::Fig1FcTwoMul;
        let sess = Session::new(fig.model()).unwrap();
        let n_threads = 4;
        let per_thread = 16;

        let mut joins = Vec::new();
        for t in 0..n_threads {
            let coord = coord.clone();
            joins.push(std::thread::spawn(move || {
                let fig = Figure::Fig1FcTwoMul;
                let mut results = Vec::new();
                for i in 0..per_thread {
                    let seed = (t * 1000 + i) as u64;
                    let x = fig.input(1, seed);
                    let resp = coord.infer("fig1_fc", x.clone()).unwrap();
                    results.push((seed, x, resp));
                }
                results
            }));
        }
        let mut total = 0;
        let mut batched_over_1 = 0;
        for j in joins {
            for (seed, x, resp) in j.join().unwrap() {
                let want = &sess.run(&[("x", x)]).unwrap()[0];
                let got = resp.output.unwrap_or_else(|e| panic!("seed {seed}: {e}"));
                assert_eq!(&got, want, "seed {seed}");
                assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
                if resp.batch_size > 1 {
                    batched_over_1 += 1;
                }
                total += 1;
            }
        }
        assert_eq!(total, n_threads * per_thread);
        // With 4 concurrent submitters and 5ms windows, at least some
        // requests must actually have been fused.
        assert!(batched_over_1 > 0, "dynamic batching never engaged");
        let stats = coord.metrics.snapshot("fig1_fc").unwrap();
        assert_eq!(stats.requests, (n_threads * per_thread) as u64);
        assert!(stats.mean_batch() > 1.0);
        coord.shutdown();
    }

    #[test]
    fn batch_transparency_property() {
        // Property: for any request interleaving, coordinator output ==
        // direct per-request execution (batching must be invisible).
        use crate::proptest_util::{run_prop, Gen, RangeUsize};
        struct Plan;
        impl Gen for Plan {
            type Value = Vec<u64>;
            fn generate(&self, rng: &mut crate::train::Rng) -> Vec<u64> {
                let n = 1 + rng.below(12);
                (0..n).map(|_| rng.next_u64() % 1000).collect()
            }
            fn shrink(&self, v: &Vec<u64>) -> Vec<Vec<u64>> {
                if v.len() > 1 {
                    vec![v[..v.len() / 2].to_vec()]
                } else {
                    Vec::new()
                }
            }
        }
        let _ = RangeUsize { lo: 0, hi: 1 }; // keep import used
        let coord = coordinator(4, 1);
        let fig = Figure::Fig1FcTwoMul;
        let sess = Session::new(fig.model()).unwrap();
        run_prop("batch_transparency", &Plan, 7, 20, |seeds| {
            let rxs: Vec<_> = seeds
                .iter()
                .map(|&s| coord.submit("fig1_fc", fig.input(1, s)).unwrap())
                .collect();
            for (&s, rx) in seeds.iter().zip(rxs) {
                let resp = rx.recv().map_err(|e| e.to_string())?;
                let got = resp.output?;
                let want = &sess.run(&[("x", fig.input(1, s))]).unwrap()[0];
                if &got != want {
                    return Err(format!("mismatch for seed {s}"));
                }
            }
            Ok(())
        });
        coord.shutdown();
    }
}
