//! Cross-backend validation — the paper's goal 3 ("closely matching
//! output (within narrow margins) on all inference environments") as an
//! operational service: fan one input set out to every backend and
//! aggregate LSB-level match reports against a designated reference —
//! plus [`InputSpec`], the per-lane admission contract the coordinator
//! checks at `submit` so a malformed request is rejected alone instead
//! of poisoning a fused batch.

use super::backend::Backend;
use crate::compare::{compare_quantized, MatchReport};
use crate::onnx::ir::{Dim, Model};
use crate::tensor::{DType, Tensor};
use anyhow::Result;
use std::sync::Arc;

/// What a lane accepts: dtype, rank, and the fixed dims of the model's
/// (single) runtime input. Axis constraints of `None` (symbolic dims —
/// the batch axis, typically) accept any extent. Checked at admission
/// time by [`Coordinator::submit`](super::Coordinator::submit), BEFORE a
/// request can be fused with others: one bad request then costs only
/// itself a typed `InvalidInput` rejection, never a co-batched
/// neighbor's answer. The spec check also runs BEFORE the lane's
/// circuit-breaker admission gate, so a malformed request keeps its
/// deterministic `InvalidInput` classification even while the lane's
/// backend is mid-outage and everything else is shed `CircuitOpen` —
/// the fault-injection chaos tests rely on that ordering.
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub dtype: DType,
    /// Per-axis expectation, index 0 = batch axis.
    pub dims: Vec<Option<usize>>,
}

impl InputSpec {
    /// The admission contract of `model`'s first runtime input (the
    /// coordinator serves single-input models), or `None` when the model
    /// declares no runtime inputs.
    pub fn from_model(model: &Model) -> Option<InputSpec> {
        let vi = model.graph.runtime_inputs().first().copied()?;
        Some(InputSpec {
            dtype: vi.dtype,
            dims: vi
                .shape
                .iter()
                .map(|d| match d {
                    Dim::Fixed(n) => Some(*n),
                    Dim::Symbolic(_) => None,
                })
                .collect(),
        })
    }

    /// Validate one request tensor against the contract. The error string
    /// names exactly what mismatched (it travels to the client inside
    /// `RejectReason::InvalidInput`).
    pub fn check(&self, t: &Tensor) -> Result<(), String> {
        if t.dtype() != self.dtype {
            return Err(format!(
                "dtype {} does not match the model input dtype {}",
                t.dtype(),
                self.dtype
            ));
        }
        if t.shape().len() != self.dims.len() {
            return Err(format!(
                "rank {} does not match the model input rank {}",
                t.shape().len(),
                self.dims.len()
            ));
        }
        for (axis, (&got, want)) in t.shape().iter().zip(&self.dims).enumerate() {
            if let Some(want) = want {
                if got != *want {
                    return Err(format!(
                        "axis {axis} has extent {got}, model requires {want}"
                    ));
                }
            }
        }
        if !self.dims.is_empty() && t.shape()[0] == 0 {
            return Err("empty batch (0 rows)".to_string());
        }
        Ok(())
    }
}

/// Agreement of one backend against the reference backend.
#[derive(Debug)]
pub struct ValidationRow {
    pub backend: String,
    pub report: MatchReport,
}

/// Outcome of a validation sweep.
#[derive(Debug)]
pub struct ValidationReport {
    pub model: String,
    pub reference: String,
    pub inputs: usize,
    pub rows: Vec<ValidationRow>,
}

impl ValidationReport {
    /// True if every backend matches within `lsb_tol` everywhere.
    pub fn all_within(&self, lsb_tol: i32) -> bool {
        self.rows.iter().all(|r| r.report.max_abs_diff <= lsb_tol)
    }

    /// Human-readable table.
    pub fn table(&self) -> String {
        let mut out = format!(
            "{}: {} inputs, reference = {}\n",
            self.model, self.inputs, self.reference
        );
        out.push_str("backend  | exact%   | <=1 LSB% | max diff | mean diff\n");
        for r in &self.rows {
            out.push_str(&format!(
                "{:<8} | {:>7.3}% | {:>7.3}% | {:>8} | {:>9.5}\n",
                r.backend,
                100.0 * r.report.exact_rate(),
                100.0 * r.report.within(1),
                r.report.max_abs_diff,
                r.report.mean_abs_diff,
            ));
        }
        out
    }
}

/// Run `inputs` through every backend; compare each against
/// `backends[0]` (the reference, normally the interpreter).
pub fn validate(
    model: &str,
    backends: &[Arc<dyn Backend>],
    inputs: &[Tensor],
) -> Result<ValidationReport> {
    assert!(!backends.is_empty());
    let reference = &backends[0];
    let mut rows: Vec<ValidationRow> = backends[1..]
        .iter()
        .map(|b| ValidationRow {
            backend: b.name().to_string(),
            report: MatchReport::default(),
        })
        .collect();
    for input in inputs {
        let want = reference.run_batch(input)?;
        for (row, backend) in rows.iter_mut().zip(&backends[1..]) {
            let got = backend.run_batch(input)?;
            row.report.merge(&compare_quantized(&want, &got, 16));
        }
    }
    Ok(ValidationReport {
        model: model.to_string(),
        reference: reference.name().to_string(),
        inputs: inputs.len(),
        rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{HwSimBackend, InterpBackend};
    use crate::figures::Figure;
    use crate::hwsim::HwConfig;

    #[test]
    fn input_spec_checks_dtype_rank_and_fixed_dims() {
        let fig = Figure::Fig1FcTwoMul;
        let spec = InputSpec::from_model(&fig.model()).unwrap();
        // The fig models take [N, 64] i8 inputs: batch axis free.
        assert!(spec.check(&fig.input(1, 0)).is_ok());
        assert!(spec.check(&fig.input(7, 0)).is_ok());
        // Wrong dtype.
        let bad = Tensor::from_f32(&[1, 64], vec![0.0; 64]).unwrap();
        assert!(spec.check(&bad).unwrap_err().contains("dtype"));
        // Wrong rank.
        let bad = Tensor::from_i8(&[64], vec![0; 64]).unwrap();
        assert!(spec.check(&bad).unwrap_err().contains("rank"));
        // Wrong feature dim.
        let bad = Tensor::from_i8(&[1, 63], vec![0; 63]).unwrap();
        assert!(spec.check(&bad).unwrap_err().contains("axis 1"));
        // Empty batch.
        let bad = Tensor::from_i8(&[0, 64], vec![]).unwrap();
        assert!(spec.check(&bad).unwrap_err().contains("empty"));
    }

    #[test]
    fn interp_vs_hwsim_narrow_margins() {
        for fig in [Figure::Fig1FcTwoMul, Figure::Fig2FcReluOneMul] {
            let model = fig.model();
            let backends: Vec<Arc<dyn Backend>> = vec![
                Arc::new(InterpBackend::new(model.clone()).unwrap()),
                Arc::new(HwSimBackend::new(&model, HwConfig::default()).unwrap()),
            ];
            let inputs: Vec<Tensor> = (0..10).map(|s| fig.input(4, s)).collect();
            let report = validate(fig.name(), &backends, &inputs).unwrap();
            assert!(report.all_within(1), "{}", report.table());
            assert!(report.rows[0].report.exact_rate() > 0.99);
            assert!(report.table().contains("hwsim"));
        }
    }
}
