//! Cross-backend validation — the paper's goal 3 ("closely matching
//! output (within narrow margins) on all inference environments") as an
//! operational service: fan one input set out to every backend and
//! aggregate LSB-level match reports against a designated reference.

use super::backend::Backend;
use crate::compare::{compare_quantized, MatchReport};
use crate::tensor::Tensor;
use anyhow::Result;
use std::sync::Arc;

/// Agreement of one backend against the reference backend.
#[derive(Debug)]
pub struct ValidationRow {
    pub backend: String,
    pub report: MatchReport,
}

/// Outcome of a validation sweep.
#[derive(Debug)]
pub struct ValidationReport {
    pub model: String,
    pub reference: String,
    pub inputs: usize,
    pub rows: Vec<ValidationRow>,
}

impl ValidationReport {
    /// True if every backend matches within `lsb_tol` everywhere.
    pub fn all_within(&self, lsb_tol: i32) -> bool {
        self.rows.iter().all(|r| r.report.max_abs_diff <= lsb_tol)
    }

    /// Human-readable table.
    pub fn table(&self) -> String {
        let mut out = format!(
            "{}: {} inputs, reference = {}\n",
            self.model, self.inputs, self.reference
        );
        out.push_str("backend  | exact%   | <=1 LSB% | max diff | mean diff\n");
        for r in &self.rows {
            out.push_str(&format!(
                "{:<8} | {:>7.3}% | {:>7.3}% | {:>8} | {:>9.5}\n",
                r.backend,
                100.0 * r.report.exact_rate(),
                100.0 * r.report.within(1),
                r.report.max_abs_diff,
                r.report.mean_abs_diff,
            ));
        }
        out
    }
}

/// Run `inputs` through every backend; compare each against
/// `backends[0]` (the reference, normally the interpreter).
pub fn validate(
    model: &str,
    backends: &[Arc<dyn Backend>],
    inputs: &[Tensor],
) -> Result<ValidationReport> {
    assert!(!backends.is_empty());
    let reference = &backends[0];
    let mut rows: Vec<ValidationRow> = backends[1..]
        .iter()
        .map(|b| ValidationRow {
            backend: b.name().to_string(),
            report: MatchReport::default(),
        })
        .collect();
    for input in inputs {
        let want = reference.run_batch(input)?;
        for (row, backend) in rows.iter_mut().zip(&backends[1..]) {
            let got = backend.run_batch(input)?;
            row.report.merge(&compare_quantized(&want, &got, 16));
        }
    }
    Ok(ValidationReport {
        model: model.to_string(),
        reference: reference.name().to_string(),
        inputs: inputs.len(),
        rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{HwSimBackend, InterpBackend};
    use crate::figures::Figure;
    use crate::hwsim::HwConfig;

    #[test]
    fn interp_vs_hwsim_narrow_margins() {
        for fig in [Figure::Fig1FcTwoMul, Figure::Fig2FcReluOneMul] {
            let model = fig.model();
            let backends: Vec<Arc<dyn Backend>> = vec![
                Arc::new(InterpBackend::new(model.clone()).unwrap()),
                Arc::new(HwSimBackend::new(&model, HwConfig::default()).unwrap()),
            ];
            let inputs: Vec<Tensor> = (0..10).map(|s| fig.input(4, s)).collect();
            let report = validate(fig.name(), &backends, &inputs).unwrap();
            assert!(report.all_within(1), "{}", report.table());
            assert!(report.rows[0].report.exact_rate() > 0.99);
            assert!(report.table().contains("hwsim"));
        }
    }
}
