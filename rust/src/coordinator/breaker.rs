//! Per-lane circuit breaker.
//!
//! When a lane's backend fails batch after batch (a wedged simulator, a
//! miscompiled kernel, an accelerator that lost its device), continuing
//! to queue traffic into it only converts every request into a slow
//! failure after `max_wait` + an exec attempt. The breaker converts
//! that into a *fast*, typed failure at admission time
//! (`RejectReason::CircuitOpen`), then probes the backend with a
//! trickle of real traffic before re-opening the floodgates.
//!
//! Classic three-state machine (Nygard, *Release It!*):
//!
//! ```text
//!          K consecutive failed batches
//!   Closed ───────────────────────────▶ Open ⟲ (sheds, cooldown)
//!     ▲                                  │ cooldown elapsed,
//!     │ probe batch succeeds             │ next admit becomes a probe
//!     └──────────── HalfOpen ◀───────────┘
//!                      │ probe batch fails → back to Open (fresh cooldown)
//! ```
//!
//! The struct is **pure state**: every transition takes `now: Instant`
//! as a parameter and nothing inside reads the clock, so unit tests
//! drive the full cycle deterministically with synthetic instants. The
//! coordinator stores it behind a tiny `Mutex` in `Lane` (uncontended:
//! admission and batch-completion touch it for nanoseconds) and calls:
//!
//! * [`CircuitBreaker::admit`] from `submit()` after spec validation —
//!   `false` means shed with `CircuitOpen`;
//! * [`CircuitBreaker::on_batch`] from `replica_worker` after each
//!   batch with its success/failure fate.
//!
//! A batch fails for breaker purposes when `run_batch` returns an error
//! or panics — a lane-level "backend is sick" signal. Per-request sheds
//! (queue full, deadline) never count: those are load problems, and the
//! breaker must not open under load the controller should absorb.

use std::time::{Duration, Instant};

/// Breaker tuning knobs (`ServerConfig::breaker`; `None` disables the
/// breaker entirely — the default, preserving pre-fault behavior).
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Consecutive failed batches that trip Closed → Open.
    pub failures_to_open: u32,
    /// How long Open sheds before allowing half-open probes.
    pub cooldown: Duration,
    /// Requests admitted as probes while HalfOpen (further admits shed
    /// until a probe batch reports back).
    pub half_open_probes: u32,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig {
            failures_to_open: 5,
            cooldown: Duration::from_millis(250),
            half_open_probes: 2,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: admitting everything, counting consecutive failures.
    Closed,
    /// Tripped: shedding everything until the cooldown deadline.
    Open,
    /// Probing: a bounded number of requests admitted; their batch fate
    /// decides Closed (success) or Open again (failure).
    HalfOpen,
}

#[derive(Debug)]
enum State {
    Closed { consecutive_failures: u32 },
    Open { until: Instant },
    HalfOpen { admitted: u32 },
}

#[derive(Debug)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    state: State,
    /// Lifetime Closed/HalfOpen→Open transitions (metrics surface this).
    trips: u64,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            cfg,
            state: State::Closed {
                consecutive_failures: 0,
            },
            trips: 0,
        }
    }

    pub fn state(&self) -> BreakerState {
        match self.state {
            State::Closed { .. } => BreakerState::Closed,
            State::Open { .. } => BreakerState::Open,
            State::HalfOpen { .. } => BreakerState::HalfOpen,
        }
    }

    pub fn trips(&self) -> u64 {
        self.trips
    }

    /// Admission check for one request. `true` ⇒ let it into the queue;
    /// `false` ⇒ shed with `RejectReason::CircuitOpen`. Transitions
    /// Open → HalfOpen when the cooldown has elapsed (the admitted
    /// request IS the first probe).
    pub fn admit(&mut self, now: Instant) -> bool {
        match &mut self.state {
            State::Closed { .. } => true,
            State::Open { until } => {
                if now < *until {
                    false
                } else {
                    self.state = State::HalfOpen { admitted: 1 };
                    true
                }
            }
            State::HalfOpen { admitted } => {
                if *admitted < self.cfg.half_open_probes {
                    *admitted += 1;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record the fate of one executed batch (`ok = false` for an exec
    /// error or a caught panic). Returns `true` when this call tripped
    /// the breaker open (the caller records a metrics event). Late
    /// results arriving while Open — a probe batch from a previous
    /// half-open round, an in-flight batch from before the trip — are
    /// ignored rather than extending or resetting the cooldown.
    pub fn on_batch(&mut self, ok: bool, now: Instant) -> bool {
        match &mut self.state {
            State::Closed {
                consecutive_failures,
            } => {
                if ok {
                    *consecutive_failures = 0;
                    false
                } else {
                    *consecutive_failures += 1;
                    if *consecutive_failures >= self.cfg.failures_to_open {
                        self.trip(now);
                        true
                    } else {
                        false
                    }
                }
            }
            State::Open { .. } => false,
            State::HalfOpen { .. } => {
                if ok {
                    self.state = State::Closed {
                        consecutive_failures: 0,
                    };
                    false
                } else {
                    self.trip(now);
                    true
                }
            }
        }
    }

    fn trip(&mut self, now: Instant) {
        self.trips += 1;
        self.state = State::Open {
            until: now + self.cfg.cooldown,
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BreakerConfig {
        BreakerConfig {
            failures_to_open: 3,
            cooldown: Duration::from_millis(100),
            half_open_probes: 2,
        }
    }

    #[test]
    fn full_cycle_closed_open_half_open_closed() {
        let t0 = Instant::now();
        let mut b = CircuitBreaker::new(cfg());
        assert_eq!(b.state(), BreakerState::Closed);

        // Two failures: still closed, still admitting.
        assert!(!b.on_batch(false, t0));
        assert!(!b.on_batch(false, t0));
        assert!(b.admit(t0));
        // Third consecutive failure trips it.
        assert!(b.on_batch(false, t0));
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 1);

        // Sheds for the whole cooldown.
        assert!(!b.admit(t0));
        assert!(!b.admit(t0 + Duration::from_millis(99)));

        // Cooldown over: first admit becomes probe #1 (HalfOpen).
        let t1 = t0 + Duration::from_millis(101);
        assert!(b.admit(t1));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        // Probe #2 admitted, #3 shed (probe cap).
        assert!(b.admit(t1));
        assert!(!b.admit(t1));

        // Probe batch succeeds: closed again, admitting freely.
        assert!(!b.on_batch(true, t1));
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.admit(t1));
    }

    #[test]
    fn failed_probe_reopens_with_fresh_cooldown() {
        let t0 = Instant::now();
        let mut b = CircuitBreaker::new(cfg());
        for _ in 0..3 {
            b.on_batch(false, t0);
        }
        let t1 = t0 + Duration::from_millis(150);
        assert!(b.admit(t1)); // half-open probe
        assert!(b.on_batch(false, t1)); // probe fails → trips again
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 2);
        // The cooldown restarts from t1, not t0.
        assert!(!b.admit(t1 + Duration::from_millis(99)));
        assert!(b.admit(t1 + Duration::from_millis(101)));
    }

    #[test]
    fn success_resets_the_consecutive_failure_count() {
        let t0 = Instant::now();
        let mut b = CircuitBreaker::new(cfg());
        // failure, failure, success, failure, failure … never reaches 3.
        for _ in 0..4 {
            assert!(!b.on_batch(false, t0));
            assert!(!b.on_batch(false, t0));
            assert!(!b.on_batch(true, t0));
        }
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.trips(), 0);
    }

    #[test]
    fn late_results_while_open_are_ignored() {
        let t0 = Instant::now();
        let mut b = CircuitBreaker::new(cfg());
        for _ in 0..3 {
            b.on_batch(false, t0);
        }
        assert_eq!(b.state(), BreakerState::Open);
        // In-flight batches from before the trip report in: no state
        // change, no extra trips, cooldown deadline untouched.
        assert!(!b.on_batch(true, t0 + Duration::from_millis(50)));
        assert!(!b.on_batch(false, t0 + Duration::from_millis(60)));
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 1);
        assert!(b.admit(t0 + Duration::from_millis(101)));
    }
}
