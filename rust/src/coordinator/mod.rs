//! L3 serving coordinator.
//!
//! Thread-based (tokio is unavailable offline; std-thread replica pools
//! per model lane are the right shape for a CPU inference server
//! anyway): request router + sharded dynamic batcher with admission
//! control ([`server`]: bounded lane queues, N replicas per lane sharing
//! one compiled plan, typed [`server::RejectReason`] shedding, graceful
//! drain, and an optional serving-time controller — see
//! [`crate::tune::ControllerConfig`] — that retargets per-lane replica
//! counts and batch windows from live metrics), pluggable execution
//! backends ([`backend`]: interpreter /
//! hwsim / PJRT artifacts), serving metrics ([`metrics`]) and the
//! cross-backend narrow-margins validation service plus the per-lane
//! admission contract ([`validate`]).
//!
//! Fault tolerance rides through the same layers: backend panics are
//! unwind-isolated into typed `BackendPanic` responses, every lock
//! recovers from poisoning, a per-lane circuit breaker ([`breaker`])
//! sheds fast while a backend is sick, heartbeat supervision respawns
//! dead replicas under a restart budget, and a deterministic
//! fault-injection harness ([`fault`]) drives all of it in tests
//! without wall-clock randomness.

pub mod backend;
pub mod breaker;
pub mod fault;
pub mod metrics;
pub mod server;
pub mod validate;

pub use backend::{
    concat_batch, concat_batch_owned, pad_batch, slice_batch, split_batch, Backend, HwSimBackend,
    InterpBackend, PjrtBackend,
};
pub use breaker::{BreakerConfig, BreakerState, CircuitBreaker};
pub use fault::{FaultCounters, FaultInjectingBackend, FaultKind, FaultPlan, ReplicaAbort};
pub use metrics::{BatchFate, FaultEvent, LatencyHist, Metrics, ModelStats, ShedKind};
pub use server::{
    default_replicas, Coordinator, CoordinatorBuilder, RejectReason, Response, ServeError,
    ServerConfig, SupervisorConfig,
};
pub use validate::{validate, InputSpec, ValidationReport, ValidationRow};
