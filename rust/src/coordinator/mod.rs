//! L3 serving coordinator.
//!
//! Thread-based (tokio is unavailable offline; std-thread replica pools
//! per model lane are the right shape for a CPU inference server
//! anyway): request router + sharded dynamic batcher with admission
//! control ([`server`]: bounded lane queues, N replicas per lane sharing
//! one compiled plan, typed [`server::RejectReason`] shedding, graceful
//! drain, and an optional serving-time controller — see
//! [`crate::tune::ControllerConfig`] — that retargets per-lane replica
//! counts and batch windows from live metrics), pluggable execution
//! backends ([`backend`]: interpreter /
//! hwsim / PJRT artifacts), serving metrics ([`metrics`]) and the
//! cross-backend narrow-margins validation service plus the per-lane
//! admission contract ([`validate`]).

pub mod backend;
pub mod metrics;
pub mod server;
pub mod validate;

pub use backend::{
    concat_batch, concat_batch_owned, pad_batch, slice_batch, split_batch, Backend, HwSimBackend,
    InterpBackend, PjrtBackend,
};
pub use metrics::{LatencyHist, Metrics, ModelStats, ShedKind};
pub use server::{
    default_replicas, Coordinator, CoordinatorBuilder, RejectReason, Response, ServeError,
    ServerConfig,
};
pub use validate::{validate, InputSpec, ValidationReport, ValidationRow};
