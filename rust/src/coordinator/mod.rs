//! L3 serving coordinator.
//!
//! Thread-based (tokio is unavailable offline; a std-thread worker per
//! model lane is the right shape for a CPU inference server anyway):
//! request router + dynamic batcher ([`server`]), pluggable execution
//! backends ([`backend`]: interpreter / hwsim / PJRT artifacts), serving
//! metrics ([`metrics`]) and the cross-backend narrow-margins validation
//! service ([`validate`]).

pub mod backend;
pub mod metrics;
pub mod server;
pub mod validate;

pub use backend::{
    concat_batch, pad_batch, slice_batch, split_batch, Backend, HwSimBackend, InterpBackend,
    PjrtBackend,
};
pub use metrics::{LatencyHist, Metrics, ModelStats};
pub use server::{Coordinator, CoordinatorBuilder, Response, ServerConfig};
pub use validate::{validate, ValidationReport, ValidationRow};
