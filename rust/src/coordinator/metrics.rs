//! Serving metrics: request counters, latency histograms, batch-size
//! accounting, and fault/supervision event counters. Lock-guarded
//! (std-thread coordinator; contention is a few atomics per request,
//! far off the hot path of the actual math). All locks go through
//! [`crate::parallel::lock_recover`]: metrics must stay observable
//! *especially* while replicas are panicking, which is exactly when a
//! poisoning `lock().unwrap()` would take the whole store down.

use crate::parallel::lock_recover;
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

/// Log2-bucketed latency histogram (microseconds, buckets 1us..~1s).
#[derive(Clone, Debug)]
pub struct LatencyHist {
    buckets: Vec<u64>, // bucket i covers [2^i, 2^(i+1)) us
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist {
            buckets: vec![0; 32],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }
}

impl LatencyHist {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Approximate quantile (upper edge of the bucket containing it).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Cumulative sum of recorded latencies (microseconds) — lets the
    /// serving controller compute exact per-interval means by diffing
    /// two snapshots.
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }
}

/// Why the coordinator shed a request without executing it. Mirrors
/// `server::RejectReason` shorn of payloads (metrics count, they don't
/// describe).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedKind {
    QueueFull,
    DeadlineExceeded,
    InvalidInput,
    /// The lane's circuit breaker was open (backend failing, shedding
    /// fast instead of queueing into a sick lane).
    CircuitOpen,
}

/// How one executed batch ended — every fused request in it shares this
/// fate (batch transparency holds for failures too).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchFate {
    /// `run_batch` returned `Ok` and the split matched the fused rows.
    Success,
    /// `run_batch` returned a typed error (`ServeError::Exec`).
    Error,
    /// `run_batch` (or concat/split) panicked and was isolated
    /// (`ServeError::BackendPanic`).
    Panic,
}

/// Supervision/fault events — rare, lane-level occurrences counted
/// separately from the per-request flow.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultEvent {
    /// The supervisor respawned a dead replica worker.
    ReplicaRestart,
    /// The supervisor flagged a live-but-silent replica (heartbeat
    /// older than the configured timeout; likely wedged in a backend
    /// call it cannot be forced out of).
    ReplicaWedged,
    /// A replica slot ran out of restart budget and was abandoned.
    RestartBudgetExhausted,
    /// The lane's circuit breaker tripped open.
    BreakerOpen,
}

/// Per-model serving statistics.
///
/// Two distinct batch notions are tracked (they diverge as soon as a
/// client submits a multi-row tensor): `batch_requests_sum` counts fused
/// REQUESTS per execution, `batch_rows_sum` counts fused ROWS — the old
/// single `batch_size` conflated them (requests in the metrics, rows in
/// the response).
#[derive(Clone, Debug, Default)]
pub struct ModelStats {
    /// Requests that reached execution (shed requests are NOT counted
    /// here — see the `shed_*` counters).
    pub requests: u64,
    pub batches: u64,
    /// Requests answered with a typed execution error.
    pub errors: u64,
    /// Requests answered `BackendPanic` (isolated backend panics).
    pub panics: u64,
    /// Sum over batches of fused request counts.
    pub batch_requests_sum: u64,
    /// Sum over batches of fused row counts (axis-0 extents).
    pub batch_rows_sum: u64,
    /// Admission-shed: lane queue was at its depth cap.
    pub shed_queue_full: u64,
    /// Shed at dequeue: the request's deadline had already passed.
    pub shed_deadline: u64,
    /// Admission-rejected: dtype/rank/dims failed the lane's `InputSpec`.
    pub shed_invalid: u64,
    /// Admission-shed: the lane's circuit breaker was open.
    pub shed_circuit: u64,
    /// Replica workers respawned by the supervisor.
    pub restarts: u64,
    /// Wedged-replica detections (heartbeat silence past the timeout).
    pub wedged: u64,
    /// Circuit-breaker trips (Closed/HalfOpen → Open transitions).
    pub breaker_opens: u64,
    /// Replica slots abandoned after exhausting their restart budget.
    pub restart_budget_exhausted: u64,
    pub queue: LatencyHist,
    pub exec: LatencyHist,
    pub e2e: LatencyHist,
}

impl ModelStats {
    /// Mean fused requests per executed batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_requests_sum as f64 / self.batches as f64
        }
    }

    /// Mean fused rows per executed batch.
    pub fn mean_rows(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_rows_sum as f64 / self.batches as f64
        }
    }

    /// Total requests shed without execution, all causes.
    pub fn shed_total(&self) -> u64 {
        self.shed_queue_full + self.shed_deadline + self.shed_invalid + self.shed_circuit
    }

    /// Shed fraction of everything submitted (shed + executed).
    pub fn shed_rate(&self) -> f64 {
        let total = self.requests + self.shed_total();
        if total == 0 {
            0.0
        } else {
            self.shed_total() as f64 / total as f64
        }
    }
}

/// Registry-wide metrics store.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<HashMap<String, ModelStats>>,
}

impl Metrics {
    /// Record one executed batch: `requests` fused requests spanning
    /// `rows` axis-0 rows, all sharing `fate`.
    pub fn record_batch(
        &self,
        model: &str,
        requests: usize,
        rows: usize,
        queue_times: &[Duration],
        exec: Duration,
        fate: BatchFate,
    ) {
        let mut m = lock_recover(&self.inner);
        let s = m.entry(model.to_string()).or_default();
        s.requests += requests as u64;
        s.batches += 1;
        s.batch_requests_sum += requests as u64;
        s.batch_rows_sum += rows as u64;
        match fate {
            BatchFate::Success => {}
            BatchFate::Error => s.errors += requests as u64,
            BatchFate::Panic => s.panics += requests as u64,
        }
        for &q in queue_times {
            s.queue.record(q);
            s.e2e.record(q + exec);
        }
        s.exec.record(exec);
    }

    /// Record one request shed without execution.
    pub fn record_shed(&self, model: &str, kind: ShedKind) {
        let mut m = lock_recover(&self.inner);
        let s = m.entry(model.to_string()).or_default();
        match kind {
            ShedKind::QueueFull => s.shed_queue_full += 1,
            ShedKind::DeadlineExceeded => s.shed_deadline += 1,
            ShedKind::InvalidInput => s.shed_invalid += 1,
            ShedKind::CircuitOpen => s.shed_circuit += 1,
        }
    }

    /// Record one lane-level fault/supervision event.
    pub fn record_fault_event(&self, model: &str, event: FaultEvent) {
        let mut m = lock_recover(&self.inner);
        let s = m.entry(model.to_string()).or_default();
        match event {
            FaultEvent::ReplicaRestart => s.restarts += 1,
            FaultEvent::ReplicaWedged => s.wedged += 1,
            FaultEvent::RestartBudgetExhausted => s.restart_budget_exhausted += 1,
            FaultEvent::BreakerOpen => s.breaker_opens += 1,
        }
    }

    pub fn snapshot(&self, model: &str) -> Option<ModelStats> {
        lock_recover(&self.inner).get(model).cloned()
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = lock_recover(&self.inner).keys().cloned().collect();
        v.sort();
        v
    }

    /// Formatted per-model report lines.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for model in self.models() {
            if let Some(s) = self.snapshot(&model) {
                out.push_str(&format!(
                    "{model}: {} reqs in {} batches (mean {:.2} reqs / {:.2} rows per batch, \
                     {} errors, {} panics, shed {}: {} queue-full / {} deadline / {} invalid / {} circuit)\n  \
                     e2e p50 {}us p95 {}us p99 {}us max {}us | exec mean {:.0}us | queue mean {:.0}us\n",
                    s.requests,
                    s.batches,
                    s.mean_batch(),
                    s.mean_rows(),
                    s.errors,
                    s.panics,
                    s.shed_total(),
                    s.shed_queue_full,
                    s.shed_deadline,
                    s.shed_invalid,
                    s.shed_circuit,
                    s.e2e.quantile_us(0.5),
                    s.e2e.quantile_us(0.95),
                    s.e2e.quantile_us(0.99),
                    s.e2e.max_us(),
                    s.exec.mean_us(),
                    s.queue.mean_us(),
                ));
                if s.restarts + s.wedged + s.breaker_opens + s.restart_budget_exhausted > 0 {
                    out.push_str(&format!(
                        "  faults: {} restarts / {} wedged / {} breaker-opens / {} budget-exhausted\n",
                        s.restarts, s.wedged, s.breaker_opens, s.restart_budget_exhausted,
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHist::default();
        for us in [1u64, 10, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.95));
        assert!(h.quantile_us(0.95) <= h.quantile_us(1.0).max(h.max_us()));
    }

    #[test]
    fn metrics_accumulate() {
        let m = Metrics::default();
        // 4 single-row requests fused, then 2 requests spanning 7 rows
        // (one of them multi-row): requests and rows diverge.
        m.record_batch(
            "fig1",
            4,
            4,
            &[Duration::from_micros(5); 4],
            Duration::from_micros(100),
            BatchFate::Success,
        );
        m.record_batch(
            "fig1",
            2,
            7,
            &[Duration::from_micros(5); 2],
            Duration::from_micros(80),
            BatchFate::Success,
        );
        let s = m.snapshot("fig1").unwrap();
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch(), 3.0);
        assert_eq!(s.mean_rows(), 5.5);
        assert!(m.report().contains("fig1"));
    }

    #[test]
    fn shed_counters_accumulate_by_kind() {
        let m = Metrics::default();
        m.record_shed("fig1", ShedKind::QueueFull);
        m.record_shed("fig1", ShedKind::QueueFull);
        m.record_shed("fig1", ShedKind::DeadlineExceeded);
        m.record_shed("fig1", ShedKind::InvalidInput);
        m.record_shed("fig1", ShedKind::CircuitOpen);
        m.record_batch(
            "fig1",
            1,
            1,
            &[Duration::from_micros(5)],
            Duration::from_micros(10),
            BatchFate::Success,
        );
        let s = m.snapshot("fig1").unwrap();
        assert_eq!(s.shed_queue_full, 2);
        assert_eq!(s.shed_deadline, 1);
        assert_eq!(s.shed_invalid, 1);
        assert_eq!(s.shed_circuit, 1);
        assert_eq!(s.shed_total(), 5);
        assert_eq!(s.shed_rate(), 5.0 / 6.0);
        assert!(m.report().contains("shed 5"));
    }

    #[test]
    fn batch_fates_split_error_and_panic_counters() {
        let m = Metrics::default();
        let q = [Duration::from_micros(5); 2];
        m.record_batch("f", 2, 2, &q, Duration::from_micros(10), BatchFate::Error);
        m.record_batch("f", 2, 2, &q, Duration::from_micros(10), BatchFate::Panic);
        m.record_batch("f", 2, 2, &q, Duration::from_micros(10), BatchFate::Success);
        let s = m.snapshot("f").unwrap();
        assert_eq!(s.requests, 6);
        assert_eq!(s.errors, 2);
        assert_eq!(s.panics, 2);
        assert!(m.report().contains("2 panics"));
    }

    #[test]
    fn fault_events_accumulate() {
        let m = Metrics::default();
        m.record_fault_event("f", FaultEvent::ReplicaRestart);
        m.record_fault_event("f", FaultEvent::ReplicaRestart);
        m.record_fault_event("f", FaultEvent::ReplicaWedged);
        m.record_fault_event("f", FaultEvent::BreakerOpen);
        m.record_fault_event("f", FaultEvent::RestartBudgetExhausted);
        let s = m.snapshot("f").unwrap();
        assert_eq!(s.restarts, 2);
        assert_eq!(s.wedged, 1);
        assert_eq!(s.breaker_opens, 1);
        assert_eq!(s.restart_budget_exhausted, 1);
        assert!(m.report().contains("2 restarts"));
    }

    /// Regression for the pre-fault-tolerance cascade: a thread
    /// panicking while holding the metrics lock used to poison it, and
    /// every later `record_*`/`snapshot` — i.e. every request on every
    /// lane — would then panic in `lock().unwrap()`. With
    /// `lock_recover` the store survives and keeps counting.
    #[test]
    fn metrics_survive_a_poisoned_lock() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let m = Metrics::default();
        m.record_shed("f", ShedKind::QueueFull);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _guard = m.inner.lock().unwrap();
            panic!("die holding the metrics lock");
        }));
        assert!(m.inner.is_poisoned(), "setup must actually poison");
        // Every entry point still works on the poisoned mutex.
        m.record_shed("f", ShedKind::QueueFull);
        m.record_batch(
            "f",
            1,
            1,
            &[Duration::from_micros(1)],
            Duration::from_micros(1),
            BatchFate::Success,
        );
        m.record_fault_event("f", FaultEvent::BreakerOpen);
        let s = m.snapshot("f").unwrap();
        assert_eq!(s.shed_queue_full, 2);
        assert_eq!(s.requests, 1);
        assert_eq!(s.breaker_opens, 1);
        assert!(!m.models().is_empty());
        assert!(!m.report().is_empty());
    }
}
