//! Serving metrics: request counters, latency histograms, batch-size
//! accounting. Lock-guarded (std-thread coordinator; contention is a
//! few atomics per request, far off the hot path of the actual math).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

/// Log2-bucketed latency histogram (microseconds, buckets 1us..~1s).
#[derive(Clone, Debug)]
pub struct LatencyHist {
    buckets: Vec<u64>, // bucket i covers [2^i, 2^(i+1)) us
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist {
            buckets: vec![0; 32],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }
}

impl LatencyHist {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Approximate quantile (upper edge of the bucket containing it).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }
}

/// Per-model serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ModelStats {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    pub batch_size_sum: u64,
    pub queue: LatencyHist,
    pub exec: LatencyHist,
    pub e2e: LatencyHist,
}

impl ModelStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }
}

/// Registry-wide metrics store.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<HashMap<String, ModelStats>>,
}

impl Metrics {
    pub fn record_batch(
        &self,
        model: &str,
        batch: usize,
        queue_times: &[Duration],
        exec: Duration,
        errored: bool,
    ) {
        let mut m = self.inner.lock().unwrap();
        let s = m.entry(model.to_string()).or_default();
        s.requests += batch as u64;
        s.batches += 1;
        s.batch_size_sum += batch as u64;
        if errored {
            s.errors += batch as u64;
        }
        for &q in queue_times {
            s.queue.record(q);
            s.e2e.record(q + exec);
        }
        s.exec.record(exec);
    }

    pub fn snapshot(&self, model: &str) -> Option<ModelStats> {
        self.inner.lock().unwrap().get(model).cloned()
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.inner.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Formatted per-model report lines.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for model in self.models() {
            if let Some(s) = self.snapshot(&model) {
                out.push_str(&format!(
                    "{model}: {} reqs in {} batches (mean batch {:.2}, {} errors)\n  \
                     e2e p50 {}us p95 {}us p99 {}us max {}us | exec mean {:.0}us | queue mean {:.0}us\n",
                    s.requests,
                    s.batches,
                    s.mean_batch(),
                    s.errors,
                    s.e2e.quantile_us(0.5),
                    s.e2e.quantile_us(0.95),
                    s.e2e.quantile_us(0.99),
                    s.e2e.max_us(),
                    s.exec.mean_us(),
                    s.queue.mean_us(),
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHist::default();
        for us in [1u64, 10, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.95));
        assert!(h.quantile_us(0.95) <= h.quantile_us(1.0).max(h.max_us()));
    }

    #[test]
    fn metrics_accumulate() {
        let m = Metrics::default();
        m.record_batch(
            "fig1",
            4,
            &[Duration::from_micros(5); 4],
            Duration::from_micros(100),
            false,
        );
        m.record_batch(
            "fig1",
            2,
            &[Duration::from_micros(5); 2],
            Duration::from_micros(80),
            false,
        );
        let s = m.snapshot("fig1").unwrap();
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch(), 3.0);
        assert!(m.report().contains("fig1"));
    }
}
