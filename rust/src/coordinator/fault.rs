//! Deterministic fault injection for the serving layer.
//!
//! Production co-design treats the backend as the *untrusted* half of
//! the stack: kernels panic, simulators reject inputs, accelerator
//! calls hang. This module makes those failures a first-class, fully
//! reproducible test input: [`FaultInjectingBackend`] wraps any real
//! [`Backend`] and injects panics / errors / delays / replica aborts
//! according to a [`FaultPlan`] — a schedule keyed by the lane-global
//! **call counter**, never by wall clock or OS randomness, so the same
//! plan replays the same fault sequence on every run (modulo which
//! replica thread happens to pick up which call, which is exactly the
//! nondeterminism the chaos tests are meant to range over).
//!
//! The fault fates map onto the serving taxonomy one-to-one:
//!
//! | injected                 | observed by the client                     |
//! |--------------------------|--------------------------------------------|
//! | [`FaultKind::Error`]     | `ServeError::Exec` (typed execution error) |
//! | [`FaultKind::Panic`]     | `ServeError::BackendPanic` (isolated)      |
//! | [`FaultKind::Abort`]     | `ServeError::BackendPanic`, then the replica
//! |                          | thread exits (supervisor territory)        |
//! | [`FaultKind::Delay`]     | a normal answer, late (deadline/breaker    |
//! |                          | territory)                                 |
//!
//! Used by `tests/fault_injection.rs` (the chaos suite, armed in CI by
//! the `fault-injection` job via `PQDL_CHAOS=full`) and the fault
//! extension of the batch-transparency property in `server.rs`.

use super::backend::Backend;
use super::validate::InputSpec;
use crate::tensor::Tensor;
use crate::train::Rng;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What a scheduled fault does to the wrapped `run_batch` call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Return an error (surfaces as `ServeError::Exec`).
    Error,
    /// Panic with a string payload (surfaces as
    /// `ServeError::BackendPanic`; the serving worker survives).
    Panic,
    /// Panic with the [`ReplicaAbort`] marker payload: the serving
    /// worker answers the whole batch `BackendPanic`, then exits its
    /// thread — the deterministic stand-in for a replica whose thread is
    /// lost (what the supervisor's restart budget exists for).
    Abort,
    /// Sleep [`FaultPlan::delay`], then execute normally (exercises
    /// deadline shedding and breaker half-open timing).
    Delay,
}

/// Marker panic payload for [`FaultKind::Abort`]. The serving worker
/// downcasts caught panic payloads against this type; a match means
/// "answer the batch, then recycle this replica thread".
pub struct ReplicaAbort;

/// Best-effort human-readable text of a caught panic payload (the
/// standard `&str` / `String` payloads `panic!` produces; anything else
/// is summarized, never dropped).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if payload.is::<ReplicaAbort>() {
        "replica aborted (injected)".to_string()
    } else {
        "non-string panic payload".to_string()
    }
}

/// A deterministic fault schedule over the lane-global call counter.
///
/// Two layers, both wall-clock-free:
///
/// * **explicit**: [`FaultPlan::at`] pins a fault to one exact call
///   index — unit tests script precise sequences with it;
/// * **seeded**: [`FaultPlan::seeded`] derives a per-call decision by
///   hashing (seed, call index) through SplitMix64, so an arbitrarily
///   long run has a fixed fault pattern at a configured rate — chaos
///   tests sweep seeds, not sleep timings.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    /// Fault probability numerator per 1000 calls (0 = seeded layer off).
    rate_per_mille: u64,
    /// Kinds the seeded layer draws from (uniformly).
    kinds: Vec<FaultKind>,
    /// Explicit call-index pins, consulted before the seeded layer.
    at: Vec<(u64, FaultKind)>,
    /// Sleep injected by [`FaultKind::Delay`].
    pub delay: Duration,
}

impl FaultPlan {
    /// The empty plan: never faults (the wrapper becomes transparent).
    pub fn none() -> FaultPlan {
        FaultPlan {
            seed: 0,
            rate_per_mille: 0,
            kinds: Vec::new(),
            at: Vec::new(),
            delay: Duration::from_millis(1),
        }
    }

    /// A seeded random schedule: each call faults with probability
    /// `rate_per_mille`/1000, drawing uniformly from `kinds`.
    pub fn seeded(seed: u64, rate_per_mille: u64, kinds: &[FaultKind]) -> FaultPlan {
        FaultPlan {
            seed,
            rate_per_mille: rate_per_mille.min(1000),
            kinds: kinds.to_vec(),
            at: Vec::new(),
            delay: Duration::from_millis(1),
        }
    }

    /// Pin `kind` to exactly call `call` (0-based; overrides the seeded
    /// layer for that call).
    pub fn at(mut self, call: u64, kind: FaultKind) -> FaultPlan {
        self.at.push((call, kind));
        self
    }

    /// Set the sleep injected by [`FaultKind::Delay`].
    pub fn with_delay(mut self, delay: Duration) -> FaultPlan {
        self.delay = delay;
        self
    }

    /// The fault scheduled for call index `call`, if any. Pure: same
    /// plan + same index ⇒ same answer, on every thread, forever.
    pub fn fault_for(&self, call: u64) -> Option<FaultKind> {
        if let Some(&(_, kind)) = self.at.iter().find(|&&(c, _)| c == call) {
            return Some(kind);
        }
        if self.rate_per_mille == 0 || self.kinds.is_empty() {
            return None;
        }
        // Key the PRNG on (seed, call) so the decision for call N never
        // depends on how many other calls ran first — replica counts and
        // interleavings change WHO hits the fault, never WHERE it is.
        let mut rng = Rng::new(self.seed ^ call.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        if rng.next_u64() % 1000 < self.rate_per_mille {
            Some(self.kinds[rng.below(self.kinds.len())])
        } else {
            None
        }
    }
}

/// Injection counters, shared across every replica of the wrapped lane
/// (tests assert against them; `total_injected` covers all kinds).
#[derive(Debug, Default)]
pub struct FaultCounters {
    /// Calls observed (faulted or not) — the schedule cursor.
    pub calls: AtomicU64,
    pub errors: AtomicU64,
    pub panics: AtomicU64,
    pub aborts: AtomicU64,
    pub delays: AtomicU64,
}

impl FaultCounters {
    pub fn total_injected(&self) -> u64 {
        self.errors.load(Ordering::SeqCst)
            + self.panics.load(Ordering::SeqCst)
            + self.aborts.load(Ordering::SeqCst)
            + self.delays.load(Ordering::SeqCst)
    }
}

/// A [`Backend`] decorator executing a [`FaultPlan`]. Forked replicas
/// share one call counter and one plan, so the schedule is **lane**-
/// global: "call #7 panics" holds no matter which replica serves it.
pub struct FaultInjectingBackend {
    inner: Arc<dyn Backend>,
    plan: Arc<FaultPlan>,
    counters: Arc<FaultCounters>,
}

impl FaultInjectingBackend {
    pub fn new(inner: Arc<dyn Backend>, plan: FaultPlan) -> FaultInjectingBackend {
        FaultInjectingBackend {
            inner,
            plan: Arc::new(plan),
            counters: Arc::new(FaultCounters::default()),
        }
    }

    /// The shared injection counters (one instance per lane).
    pub fn counters(&self) -> Arc<FaultCounters> {
        self.counters.clone()
    }
}

impl Backend for FaultInjectingBackend {
    fn name(&self) -> &str {
        "fault-inject"
    }

    fn run_batch(&self, input: &Tensor) -> Result<Tensor> {
        let call = self.counters.calls.fetch_add(1, Ordering::SeqCst);
        match self.plan.fault_for(call) {
            None => self.inner.run_batch(input),
            Some(FaultKind::Error) => {
                self.counters.errors.fetch_add(1, Ordering::SeqCst);
                bail!("injected error at call {call}")
            }
            Some(FaultKind::Panic) => {
                self.counters.panics.fetch_add(1, Ordering::SeqCst);
                panic!("injected panic at call {call}")
            }
            Some(FaultKind::Abort) => {
                self.counters.aborts.fetch_add(1, Ordering::SeqCst);
                std::panic::panic_any(ReplicaAbort)
            }
            Some(FaultKind::Delay) => {
                self.counters.delays.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(self.plan.delay);
                self.inner.run_batch(input)
            }
        }
    }

    fn fork_replica(&self) -> Option<Arc<dyn Backend>> {
        // Replicas fork the inner backend as usual but SHARE the plan,
        // counter, and counters — the schedule stays lane-global.
        let inner = self
            .inner
            .fork_replica()
            .unwrap_or_else(|| self.inner.clone());
        Some(Arc::new(FaultInjectingBackend {
            inner,
            plan: self.plan.clone(),
            counters: self.counters.clone(),
        }))
    }

    fn input_spec(&self) -> Option<InputSpec> {
        self.inner.input_spec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::InterpBackend;
    use crate::figures::Figure;

    #[test]
    fn schedule_is_deterministic_and_counter_keyed() {
        let plan = FaultPlan::seeded(0xFA17, 250, &[FaultKind::Error, FaultKind::Panic]);
        let a: Vec<Option<FaultKind>> = (0..512).map(|c| plan.fault_for(c)).collect();
        let b: Vec<Option<FaultKind>> = (0..512).map(|c| plan.fault_for(c)).collect();
        assert_eq!(a, b, "same plan must replay the same schedule");
        let hits = a.iter().filter(|f| f.is_some()).count();
        // ~25% of 512 with generous slack: the rate is real, not 0 or 1.
        assert!((60..200).contains(&hits), "got {hits} faults");
        // A different seed is a different schedule.
        let other = FaultPlan::seeded(0xBEEF, 250, &[FaultKind::Error, FaultKind::Panic]);
        let c: Vec<Option<FaultKind>> = (0..512).map(|n| other.fault_for(n)).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn explicit_pins_override_the_seeded_layer() {
        let plan = FaultPlan::none()
            .at(3, FaultKind::Panic)
            .at(5, FaultKind::Error);
        assert_eq!(plan.fault_for(0), None);
        assert_eq!(plan.fault_for(3), Some(FaultKind::Panic));
        assert_eq!(plan.fault_for(5), Some(FaultKind::Error));
        assert_eq!(plan.fault_for(6), None);
        // Rate 1000 faults every call; a pin still wins on its index.
        let always = FaultPlan::seeded(9, 1000, &[FaultKind::Error]).at(2, FaultKind::Panic);
        assert_eq!(always.fault_for(2), Some(FaultKind::Panic));
        for c in [0u64, 1, 3, 4, 100] {
            assert_eq!(always.fault_for(c), Some(FaultKind::Error));
        }
    }

    #[test]
    fn wrapper_is_transparent_without_faults_and_injects_with() {
        let fig = Figure::Fig1FcTwoMul;
        let inner = Arc::new(InterpBackend::new(fig.model()).unwrap());
        let clean = FaultInjectingBackend::new(inner.clone(), FaultPlan::none());
        let x = fig.input(2, 7);
        assert_eq!(
            clean.run_batch(&x).unwrap(),
            inner.run_batch(&x).unwrap(),
            "no-fault wrapper must be bit-transparent"
        );
        assert!(clean.input_spec().is_some());

        let faulty =
            FaultInjectingBackend::new(inner.clone(), FaultPlan::none().at(0, FaultKind::Error));
        let counters = faulty.counters();
        let err = faulty.run_batch(&x).unwrap_err();
        assert!(err.to_string().contains("injected error at call 0"));
        // Call 1 is clean again — faults are per-call, not sticky.
        assert_eq!(faulty.run_batch(&x).unwrap(), inner.run_batch(&x).unwrap());
        assert_eq!(counters.calls.load(Ordering::SeqCst), 2);
        assert_eq!(counters.errors.load(Ordering::SeqCst), 1);
        assert_eq!(counters.total_injected(), 1);
    }

    #[test]
    fn forked_replicas_share_the_schedule_cursor() {
        let fig = Figure::Fig1FcTwoMul;
        let inner = Arc::new(InterpBackend::new(fig.model()).unwrap());
        let be = FaultInjectingBackend::new(inner, FaultPlan::none().at(1, FaultKind::Error));
        let counters = be.counters();
        let replica = be.fork_replica().expect("wrapper forks");
        let x = fig.input(1, 1);
        // Call 0 through the root, call 1 through the REPLICA: the
        // replica consumes the shared cursor and hits the pinned fault.
        be.run_batch(&x).unwrap();
        assert!(replica.run_batch(&x).is_err());
        assert_eq!(counters.calls.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn panic_messages_extract_standard_payloads() {
        let p = std::panic::catch_unwind(|| panic!("plain str")).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "plain str");
        let p = std::panic::catch_unwind(|| panic!("formatted {}", 42)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "formatted 42");
        let p = std::panic::catch_unwind(|| std::panic::panic_any(ReplicaAbort)).unwrap_err();
        assert!(panic_message(p.as_ref()).contains("replica aborted"));
        let p = std::panic::catch_unwind(|| std::panic::panic_any(17u32)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "non-string panic payload");
    }
}
