//! Canonical Figure 1–6 models with deterministic parameters.
//!
//! These are the *shared ground truth* between the Rust stack and the
//! Python/JAX AOT pipeline: `python/compile/model.py` constructs the
//! same weights from the same integer formulas, so the PJRT artifacts
//! and these ONNX models describe the identical network — letting
//! `bench_goal_match` compare interpreter vs hwsim vs XLA on equal
//! footing without any weight files changing hands.
//!
//! Formulas (do not change without updating `python/compile/model.py`):
//! * weight  `w[i, j] = ((i*7 + j*3) mod 23) - 11`      (int8)
//! * bias    `b[j]    = ((j*13) mod 101) - 50`          (int32)
//! * conv kernel `w[m, c, i, j] = ((m*5 + c*3 + i*7 + j) mod 19) - 9`

use crate::onnx::ir::Attr;
use crate::onnx::{batched, GraphBuilder, Model};
use crate::quant::{decompose, QType, RescaleDecomposition};
use crate::rewrite::patterns::{emit_conv, emit_fc, ActKind, ConvParams, FcParams, RescaleOp};
use crate::tensor::{DType, Tensor};

/// Default layer sizes of the canonical FC figures.
pub const FC_IN: usize = 64;
pub const FC_OUT: usize = 32;

/// Canonical int8 FC weight `[k, n]`.
pub fn canonical_weight(k: usize, n: usize) -> Tensor {
    let data: Vec<i8> = (0..k)
        .flat_map(|i| (0..n).map(move |j| (((i * 7 + j * 3) % 23) as i8) - 11))
        .collect();
    Tensor::from_i8(&[k, n], data).unwrap()
}

/// Canonical i32 bias `[n]`.
pub fn canonical_bias(n: usize) -> Tensor {
    let data: Vec<i32> = (0..n).map(|j| ((j * 13) % 101) as i32 - 50).collect();
    Tensor::from_i32(&[n], data).unwrap()
}

/// Canonical conv kernel `[m, c, kh, kw]`.
pub fn canonical_conv_kernel(m: usize, c: usize, kh: usize, kw: usize) -> Tensor {
    let mut data = Vec::with_capacity(m * c * kh * kw);
    for mi in 0..m {
        for ci in 0..c {
            for i in 0..kh {
                for j in 0..kw {
                    data.push((((mi * 5 + ci * 3 + i * 7 + j) % 19) as i8) - 9);
                }
            }
        }
    }
    Tensor::from_i8(&[m, c, kh, kw], data).unwrap()
}

/// The canonical rescale for the FC figures: 1/192 ≈ the right magnitude
/// to keep the int8 output unsaturated with the canonical weights.
pub fn canonical_rescale() -> RescaleDecomposition {
    decompose(1.0 / 192.0, 31).unwrap()
}

/// Deterministic pseudo-random int8 input for cross-backend checks
/// (same formula as `python/compile/model.py::canonical_input`).
pub fn canonical_input(batch: usize, dim: usize, seed: u64) -> Tensor {
    let mut s = seed.wrapping_add(0x9E3779B97F4A7C15);
    let data: Vec<i8> = (0..batch * dim)
        .map(|_| {
            s = s.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            ((z ^ (z >> 31)) >> 56) as u8 as i8
        })
        .collect();
    Tensor::from_i8(&[batch, dim], data).unwrap()
}

/// Which figure pattern a canonical model realizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Figure {
    Fig1FcTwoMul,
    Fig2FcReluOneMul,
    Fig3Conv,
    Fig4TanhInt8,
    Fig5TanhF16,
    Fig6SigmoidF16,
}

impl Figure {
    pub const ALL: [Figure; 6] = [
        Figure::Fig1FcTwoMul,
        Figure::Fig2FcReluOneMul,
        Figure::Fig3Conv,
        Figure::Fig4TanhInt8,
        Figure::Fig5TanhF16,
        Figure::Fig6SigmoidF16,
    ];

    /// Stable name used for artifact files and bench output.
    pub fn name(&self) -> &'static str {
        match self {
            Figure::Fig1FcTwoMul => "fig1_fc",
            Figure::Fig2FcReluOneMul => "fig2_fc_relu",
            Figure::Fig3Conv => "fig3_conv",
            Figure::Fig4TanhInt8 => "fig4_tanh_int8",
            Figure::Fig5TanhF16 => "fig5_tanh_f16",
            Figure::Fig6SigmoidF16 => "fig6_sigmoid_f16",
        }
    }

    /// Input feature shape (without batch dim).
    pub fn input_dims(&self) -> Vec<usize> {
        match self {
            Figure::Fig3Conv => vec![1, 8, 8],
            _ => vec![FC_IN],
        }
    }

    /// Output feature shape (without batch dim).
    pub fn output_dims(&self) -> Vec<usize> {
        match self {
            Figure::Fig3Conv => vec![4, 8, 8],
            _ => vec![FC_OUT],
        }
    }

    /// Output dtype of the pattern.
    pub fn output_dtype(&self) -> DType {
        match self {
            Figure::Fig2FcReluOneMul | Figure::Fig6SigmoidF16 => DType::U8,
            _ => DType::I8,
        }
    }

    /// Interp-vs-hwsim agreement margin in output LSBs (shared by every
    /// cross-backend test so the bound has one home). A 1-LSB
    /// pre-activation difference (f32 product rounding in the interp vs
    /// exact i64 in hw) is amplified by the activation's local slope ×
    /// in_scale × output levels: fig4 tanh (in 4/127) ≤ 4, fig5 tanh
    /// (in 2/127) ≤ 2, fig6 sigmoid (in 8/127, ×255) ≤ 5; everything
    /// without an activation ROM stays within 1.
    pub fn hw_tolerance(&self) -> i32 {
        match self {
            Figure::Fig4TanhInt8 => 4,
            Figure::Fig5TanhF16 => 2,
            Figure::Fig6SigmoidF16 => 5,
            _ => 1,
        }
    }

    /// Build the canonical ONNX model for this figure (int8 I/O, exactly
    /// the operator sequences of the paper's figures).
    pub fn model(&self) -> Model {
        match self {
            Figure::Fig3Conv => {
                let params = ConvParams {
                    weight_q: canonical_conv_kernel(4, 1, 3, 3),
                    bias_q: Some(canonical_bias(4)),
                    rescale: RescaleOp::OneMul(1.0 / 64.0),
                    relu: false,
                    out_qtype: QType::I8,
                    strides: [1, 1],
                    pads: [1, 1, 1, 1],
                };
                let mut b = GraphBuilder::new(self.name());
                b.input("x", DType::I8, &batched(&[1, 8, 8]));
                let y = emit_conv(&mut b, "x", &params, "c0");
                b.output(&y, DType::I8, &batched(&[4, 8, 8]));
                b.finish_model()
            }
            _ => {
                let (rescale, activation, out_qtype) = match self {
                    Figure::Fig1FcTwoMul => (
                        RescaleOp::TwoMul(canonical_rescale()),
                        ActKind::None,
                        QType::I8,
                    ),
                    Figure::Fig2FcReluOneMul => {
                        (RescaleOp::OneMul(1.0 / 192.0), ActKind::Relu, QType::U8)
                    }
                    Figure::Fig4TanhInt8 => (
                        RescaleOp::TwoMul(decompose(127.0 / (48.0 * 127.0), 31).unwrap()),
                        ActKind::TanhInt8 {
                            in_scale: 4.0 / 127.0,
                            out_scale: 1.0 / 127.0,
                        },
                        QType::I8,
                    ),
                    Figure::Fig5TanhF16 => (
                        RescaleOp::TwoMul(decompose(127.0 / (96.0 * 127.0), 31).unwrap()),
                        ActKind::TanhF16 {
                            in_scale: 2.0 / 127.0,
                            out_scale: 1.0 / 127.0,
                        },
                        QType::I8,
                    ),
                    Figure::Fig6SigmoidF16 => (
                        RescaleOp::OneMul(127.0 / (24.0 * 127.0)),
                        ActKind::SigmoidF16 {
                            in_scale: 8.0 / 127.0,
                            out_scale: 1.0 / 255.0,
                        },
                        QType::U8,
                    ),
                    Figure::Fig3Conv => unreachable!(),
                };
                let params = FcParams {
                    weight_q: canonical_weight(FC_IN, FC_OUT),
                    bias_q: Some(canonical_bias(FC_OUT)),
                    rescale,
                    activation,
                    out_qtype,
                };
                let mut b = GraphBuilder::new(self.name());
                b.input("x", DType::I8, &batched(&[FC_IN]));
                let y = emit_fc(&mut b, "x", &params, "l0");
                b.output(&y, self.output_dtype(), &batched(&[FC_OUT]));
                b.finish_model()
            }
        }
    }

    /// Canonical input batch for this figure.
    pub fn input(&self, batch: usize, seed: u64) -> Tensor {
        let dims = self.input_dims();
        let flat: usize = dims.iter().product();
        let t = canonical_input(batch, flat, seed);
        let mut shape = vec![batch];
        shape.extend(dims);
        t.reshape(&shape).unwrap()
    }
}

/// Attribute helper used by benches to tag models.
pub fn tag(model: &mut Model, key: &str, value: &str) {
    model.metadata.push((key.to_string(), value.to_string()));
    let _ = Attr::Int(0); // keep Attr import meaningful for future tags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::Session;

    #[test]
    fn all_figures_validate_and_run() {
        for fig in Figure::ALL {
            let m = fig.model();
            crate::onnx::check_model(&m).unwrap_or_else(|e| panic!("{}: {e}", fig.name()));
            let sess = Session::new(m).unwrap();
            let x = fig.input(2, 42);
            let y = sess.run(&[("x", x)]).unwrap();
            assert_eq!(y[0].dtype(), fig.output_dtype(), "{}", fig.name());
            let mut want = vec![2usize];
            want.extend(fig.output_dims());
            assert_eq!(y[0].shape(), &want[..], "{}", fig.name());
        }
    }

    #[test]
    fn all_figures_run_on_hwsim() {
        for fig in Figure::ALL {
            let m = fig.model();
            let hw =
                crate::hwsim::HwModule::compile(&m, crate::hwsim::HwConfig::default()).unwrap();
            let sess = Session::new(m).unwrap();
            let x = fig.input(3, 7);
            let want = &sess.run(&[("x", x.clone())]).unwrap()[0];
            let (got, _) = hw.run(&x).unwrap();
            let wv = want.as_quantized_i32().unwrap();
            let gv = got.as_quantized_i32().unwrap();
            let max_diff = wv
                .iter()
                .zip(&gv)
                .map(|(a, b)| (a - b).abs())
                .max()
                .unwrap();
            let tol = fig.hw_tolerance();
            assert!(
                max_diff <= tol,
                "{}: max LSB diff {max_diff} > {tol}",
                fig.name()
            );
        }
    }

    #[test]
    fn canonical_values_stable() {
        // Pin the formulas: any change must be deliberate and mirrored in
        // python/compile/model.py.
        let w = canonical_weight(3, 3);
        assert_eq!(w.as_i8().unwrap(), &[-11, -8, -5, -4, -1, 2, 3, 6, 9]);
        let b = canonical_bias(3);
        assert_eq!(b.as_i32().unwrap(), &[-50, -37, -24]);
        let k = canonical_conv_kernel(1, 1, 2, 2);
        assert_eq!(k.as_i8().unwrap(), &[-9, -8, -2, -1]);
        let x = canonical_input(1, 4, 42);
        assert_eq!(x.as_i8().unwrap(), &[40, 71, 88, 9]);
    }
}
