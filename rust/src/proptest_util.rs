//! Minimal property-testing driver (the `proptest` crate is unavailable
//! offline).
//!
//! [`run_prop`] generates `cases` random inputs from a generator, runs
//! the property, and on failure performs greedy shrinking via the
//! generator's `shrink` implementation before reporting the minimal
//! counterexample. Deterministic: failures print the seed, and the same
//! seed reproduces the run.

use crate::train::rng::Rng;

/// A generator of random test inputs with optional shrinking.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values (tried in order during shrinking).
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Run a property over `cases` generated inputs. Panics with the
/// minimal counterexample (after shrinking) and the reproducing seed.
pub fn run_prop<G: Gen, F: Fn(&G::Value) -> Result<(), String>>(
    name: &str,
    gen: &G,
    seed: u64,
    cases: usize,
    prop: F,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut current = value;
            let mut current_msg = msg;
            'outer: loop {
                for cand in gen.shrink(&current) {
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        current_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (seed {seed}, case {case}):\n  \
                 counterexample: {current:?}\n  error: {current_msg}"
            );
        }
    }
}

/// Generator: i8 vectors of length within [min_len, max_len].
pub struct VecI8 {
    pub min_len: usize,
    pub max_len: usize,
}

impl Gen for VecI8 {
    type Value = Vec<i8>;

    fn generate(&self, rng: &mut Rng) -> Vec<i8> {
        let len = self.min_len + rng.below(self.max_len - self.min_len + 1);
        (0..len).map(|_| rng.i8()).collect()
    }

    fn shrink(&self, v: &Vec<i8>) -> Vec<Vec<i8>> {
        let mut out = Vec::new();
        // Halve the vector.
        if v.len() > self.min_len {
            let half = (v.len() / 2).max(self.min_len);
            out.push(v[..half].to_vec());
        }
        // Zero out elements.
        if let Some(pos) = v.iter().position(|&x| x != 0) {
            let mut z = v.clone();
            z[pos] = 0;
            out.push(z);
        }
        out
    }
}

/// Generator: f32 in [lo, hi] plus interesting boundary values.
pub struct RangeF32 {
    pub lo: f32,
    pub hi: f32,
}

impl Gen for RangeF32 {
    type Value = f32;

    fn generate(&self, rng: &mut Rng) -> f32 {
        // 1 in 8: pick a boundary-ish value.
        if rng.below(8) == 0 {
            let specials = [
                self.lo,
                self.hi,
                0.5 * (self.lo + self.hi),
                self.lo + f32::EPSILON,
            ];
            specials[rng.below(specials.len())]
        } else {
            rng.range_f32(self.lo, self.hi)
        }
    }

    fn shrink(&self, v: &f32) -> Vec<f32> {
        let mid = 0.5 * (self.lo + self.hi);
        if (*v - mid).abs() > 1e-6 {
            vec![mid, 0.5 * (*v + mid)]
        } else {
            Vec::new()
        }
    }
}

/// Generator: usize in [lo, hi].
pub struct RangeUsize {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for RangeUsize {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        self.lo + rng.below(self.hi - self.lo + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        if *v > self.lo {
            vec![self.lo, self.lo + (*v - self.lo) / 2]
        } else {
            Vec::new()
        }
    }
}

/// Pair generator.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        run_prop("abs_nonneg", &VecI8 { min_len: 0, max_len: 32 }, 1, 200, |v| {
            if v.iter().all(|&x| (x as i32).abs() >= 0) {
                Ok(())
            } else {
                Err("negative abs".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails' failed")]
    fn failing_property_shrinks() {
        run_prop(
            "always_fails",
            &VecI8 { min_len: 1, max_len: 64 },
            2,
            10,
            |v| {
                if v.len() >= 1 {
                    Err("too long".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn range_f32_within_bounds() {
        let gen = RangeF32 { lo: -2.0, hi: 3.0 };
        let mut rng = Rng::new(5);
        for _ in 0..500 {
            let v = gen.generate(&mut rng);
            assert!((-2.0..=3.0).contains(&v));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = VecI8 { min_len: 0, max_len: 16 };
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..50 {
            assert_eq!(gen.generate(&mut a), gen.generate(&mut b));
        }
    }
}
