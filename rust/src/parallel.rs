//! Dependency-free thread pool for the execution hot paths.
//!
//! `rayon`/`tokio` are unavailable offline, so this is a small fixed pool of
//! `std::thread` workers fed over an `mpsc` channel — the same worker shape
//! as [`crate::coordinator::server`]'s model lanes. Three layers use it:
//!
//! * [`crate::interp::Session::run`] splits the batch axis across workers,
//! * [`crate::hwsim::HwModule::run`] does the same for the simulator,
//! * [`crate::ops::matmul`] / [`crate::ops::conv`] split GEMM output rows and
//!   the conv batch loop for large single calls.
//!
//! All parallel paths are **bit-exact** with their serial counterparts: work
//! is split on independent integer/row boundaries and results are assembled
//! in deterministic chunk order (never reduced across threads), so thread
//! timing can not perturb a single output bit. `tests/parallel_exec.rs`
//! holds the property tests.
//!
//! Nested use is safe: a task that reaches a parallel entry point while
//! already running on a pool worker executes inline instead of re-enqueueing,
//! which makes pool-starvation deadlocks impossible by construction.

use std::cell::Cell;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

// --- poison-free locking ---------------------------------------------------

/// Lock a mutex, recovering from poisoning instead of propagating it.
///
/// Every `Mutex` in this pool and in the serving coordinator guards data
/// that stays structurally valid across a panic — counters, queues of
/// owned requests, pure state machines. A panicking holder can leave such
/// data *stale* (a heartbeat not yet stored, a batch claimed but not yet
/// answered) but never torn, because every guarded update is a single
/// assignment or a collection operation with no multi-step invariant
/// spanning a potential panic site. Under that contract poisoning is pure
/// collateral damage: honoring it would let one crashed worker cascade
/// into every thread that later touches the lock (the pre-fault-tolerance
/// failure mode where a dying replica could take `Coordinator::submit`
/// down with it). The original panic still surfaces on the thread that
/// panicked — only the *secondary* poison panic is suppressed.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait`] under the [`lock_recover`] poison contract.
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait_timeout`] under the [`lock_recover`] poison contract.
/// The timeout flag is dropped — every caller re-checks its condition
/// under the reacquired lock anyway.
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, dur) {
        Ok((g, _)) => g,
        Err(e) => e.into_inner().0,
    }
}

thread_local! {
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
    static SERIAL_SCOPE: Cell<usize> = const { Cell::new(0) };
}

/// True when the current thread is a pool worker (parallel entry points use
/// this to fall back to inline execution instead of nesting).
pub fn on_worker_thread() -> bool {
    IN_POOL_WORKER.with(|f| f.get())
}

/// True while the current thread is inside [`serial_scope`].
pub fn in_serial_scope() -> bool {
    SERIAL_SCOPE.with(|c| c.get() > 0)
}

/// Should this call site dispatch work to the pool? False on pool workers
/// (nested parallelism runs inline) and inside [`serial_scope`] (serial
/// reference paths must stay single-threaded to be meaningful baselines).
pub fn allow_pool_dispatch() -> bool {
    !on_worker_thread() && !in_serial_scope()
}

/// Run `f` with every parallel entry point on this thread forced to its
/// serial path — the guarantee behind `Session::run_serial` /
/// `HwModule::run_serial` being true single-thread references.
pub fn serial_scope<R>(f: impl FnOnce() -> R) -> R {
    struct Guard;
    impl Drop for Guard {
        fn drop(&mut self) {
            SERIAL_SCOPE.with(|c| c.set(c.get() - 1));
        }
    }
    SERIAL_SCOPE.with(|c| c.set(c.get() + 1));
    let _guard = Guard;
    f()
}

/// A fixed-size worker pool executing boxed jobs from a shared queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Spawn a pool with `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = rx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("pqdl-pool-{i}"))
                .spawn(move || {
                    IN_POOL_WORKER.with(|f| f.set(true));
                    loop {
                        // Hold the lock only while receiving, not while running.
                        let job = match lock_recover(&rx).recv() {
                            Ok(job) => job,
                            Err(_) => return, // all senders dropped
                        };
                        job();
                    }
                })
                .expect("spawning pool worker");
            handles.push(handle);
        }
        ThreadPool {
            tx: Some(tx),
            handles,
            threads,
        }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The process-wide pool, sized by `PQDL_THREADS` or the machine's
    /// available parallelism. Created on first use.
    pub fn global() -> &'static ThreadPool {
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(|| ThreadPool::new(default_threads()))
    }

    /// Run borrowed tasks to completion. Blocks until every task has
    /// finished (this wait is what makes handing `'scope` borrows to
    /// `'static` workers sound). The last task runs inline on the calling
    /// thread so the caller is never idle. Panics in tasks are caught on the
    /// workers and re-raised here once all tasks have settled — with the
    /// ORIGINAL payload (the first one captured), so the root cause is
    /// never masked behind a generic wrapper message.
    ///
    /// When called from a pool worker (nested parallelism) every task runs
    /// inline, guaranteeing forward progress.
    pub fn run_scoped<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if tasks.is_empty() {
            return;
        }
        if on_worker_thread() || self.threads == 1 || tasks.len() == 1 {
            for task in tasks {
                task();
            }
            return;
        }

        struct Barrier {
            remaining: AtomicUsize,
            /// First panic payload captured from a pool task; re-raised by
            /// the caller so the original panic (message, location-carrying
            /// payload, typed `panic_any` value) survives the pool hop.
            payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
            lock: Mutex<()>,
            cv: Condvar,
        }
        let barrier = Arc::new(Barrier {
            remaining: AtomicUsize::new(tasks.len() - 1),
            payload: Mutex::new(None),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        });

        let mut tasks = tasks;
        let inline = tasks.pop().expect("tasks checked non-empty");
        let tx = self.tx.as_ref().expect("pool is live");
        for task in tasks {
            // SAFETY: `task` borrows data for 'scope. We block below until
            // `remaining` reaches zero, i.e. until every enqueued task has
            // finished running (or panicked inside catch_unwind), before
            // returning — so no borrow is dangling while a worker can still
            // touch it. The transmute only erases the lifetime; the layout of
            // Box<dyn FnOnce() + Send> is identical for both lifetimes.
            let task: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(task)
            };
            let b = barrier.clone();
            let job: Job = Box::new(move || {
                if let Err(p) = panic::catch_unwind(AssertUnwindSafe(task)) {
                    let mut slot = lock_recover(&b.payload);
                    if slot.is_none() {
                        *slot = Some(p);
                    }
                }
                if b.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _guard = lock_recover(&b.lock);
                    b.cv.notify_all();
                }
            });
            tx.send(job).expect("pool workers are down");
        }

        let inline_payload = panic::catch_unwind(AssertUnwindSafe(inline)).err();

        let mut guard = lock_recover(&barrier.lock);
        while barrier.remaining.load(Ordering::SeqCst) != 0 {
            guard = wait_recover(&barrier.cv, guard);
        }
        drop(guard);

        // Resume with the original payload — first worker panic wins, the
        // inline task's as fallback. (The old behavior, a fresh
        // `panic!("parallel task panicked")`, discarded the root cause.)
        let worker_payload = lock_recover(&barrier.payload).take();
        if let Some(p) = worker_payload.or(inline_payload) {
            panic::resume_unwind(p);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Disconnect the channel so workers exit their recv loop, then join.
        self.tx = None;
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Pool size for [`ThreadPool::global`]: `PQDL_THREADS` when set, otherwise
/// the machine's available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PQDL_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Balanced split of `0..n` into `pieces` contiguous ranges (first `n %
/// pieces` ranges get one extra element). Deterministic; used everywhere a
/// parallel path splits work so serial/parallel assembly order is identical.
pub fn ranges(n: usize, pieces: usize) -> Vec<std::ops::Range<usize>> {
    let pieces = pieces.clamp(1, n.max(1));
    let base = n / pieces;
    let extra = n % pieces;
    let mut out = Vec::with_capacity(pieces);
    let mut start = 0;
    for i in 0..pieces {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// How many chunks to split `items` into for `threads` workers while keeping
/// at least `min_per_chunk` items per chunk.
pub fn chunk_count(items: usize, threads: usize, min_per_chunk: usize) -> usize {
    if items == 0 {
        return 1;
    }
    threads.min(items.div_ceil(min_per_chunk.max(1))).max(1)
}

/// Row-chunk scatter/gather shared by the batch-parallel executors
/// ([`crate::interp::Session::run`] and [`crate::hwsim::HwModule::run`]):
/// run `task` once per row range, collecting results in chunk order so
/// reassembly is deterministic regardless of thread timing.
///
/// Chunks are dispatched to `pool` unless pool dispatch is disallowed on
/// the current thread (inside [`serial_scope`], or already on a pool
/// worker), in which case every chunk runs inline in order — preserving
/// the chunk *schedule* (which hwsim's cost report is a constant of)
/// while keeping execution single-threaded. The first chunk error, in
/// chunk order, is returned.
pub fn scatter_gather<T, E, F>(
    pool: &ThreadPool,
    chunks: &[std::ops::Range<usize>],
    task: F,
) -> Result<Vec<T>, E>
where
    T: Send,
    E: Send,
    F: Fn(std::ops::Range<usize>) -> Result<T, E> + Sync,
{
    let mut results: Vec<Option<Result<T, E>>> = chunks.iter().map(|_| None).collect();
    {
        let task = &task;
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(chunks.len());
        for (slot, range) in results.iter_mut().zip(chunks) {
            let range = range.clone();
            tasks.push(Box::new(move || {
                *slot = Some(task(range));
            }));
        }
        if allow_pool_dispatch() {
            pool.run_scoped(tasks);
        } else {
            for t in tasks {
                t();
            }
        }
    }
    results
        .into_iter()
        .map(|r| r.expect("scatter_gather task completed"))
        .collect()
}

/// Parallel iteration over disjoint row-blocks of a mutable buffer laid out
/// as `rows` rows of `row_len` elements. `f(first_row, block)` is called for
/// each contiguous block; blocks are split per [`ranges`], so results are
/// identical to a serial sweep.
pub fn par_row_chunks_mut<T, F>(
    pool: &ThreadPool,
    data: &mut [T],
    rows: usize,
    row_len: usize,
    min_rows_per_chunk: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    debug_assert_eq!(data.len(), rows * row_len);
    let pieces = chunk_count(rows, pool.threads(), min_rows_per_chunk);
    if pieces <= 1 || on_worker_thread() {
        f(0, data);
        return;
    }
    let f = &f;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(pieces);
    let mut rest = data;
    for range in ranges(rows, pieces) {
        let (block, tail) = rest.split_at_mut(range.len() * row_len);
        rest = tail;
        let first_row = range.start;
        tasks.push(Box::new(move || f(first_row, block)));
    }
    pool.run_scoped(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_and_balance() {
        let r = ranges(10, 3);
        assert_eq!(r, vec![0..4, 4..7, 7..10]);
        assert_eq!(ranges(2, 8).len(), 2);
        assert_eq!(ranges(0, 4), vec![0..0]);
    }

    #[test]
    fn chunk_count_respects_grain() {
        assert_eq!(chunk_count(100, 8, 1), 8);
        assert_eq!(chunk_count(6, 8, 4), 2);
        assert_eq!(chunk_count(3, 8, 4), 1);
        assert_eq!(chunk_count(0, 8, 4), 1);
    }

    #[test]
    fn run_scoped_executes_all_with_borrows() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0usize; 64];
        {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            let mut rest = data.as_mut_slice();
            let mut idx = 0usize;
            while !rest.is_empty() {
                let (head, tail) = rest.split_at_mut(8.min(rest.len()));
                rest = tail;
                let base = idx;
                tasks.push(Box::new(move || {
                    for (i, v) in head.iter_mut().enumerate() {
                        *v = base + i;
                    }
                }));
                idx += 8;
            }
            pool.run_scoped(tasks);
        }
        let want: Vec<usize> = (0..64).collect();
        assert_eq!(data, want);
    }

    #[test]
    fn scatter_gather_orders_results_and_propagates_errors() {
        let pool = ThreadPool::new(3);
        let chunks = ranges(10, 4);
        let ok: Result<Vec<usize>, String> = scatter_gather(&pool, &chunks, |r| Ok(r.start));
        assert_eq!(ok.unwrap(), vec![0, 3, 6, 8]);
        let err: Result<Vec<usize>, String> = scatter_gather(&pool, &chunks, |r| {
            if r.start == 3 {
                Err("boom".to_string())
            } else {
                Ok(r.start)
            }
        });
        assert_eq!(err.unwrap_err(), "boom");
        // Inside serial_scope the same chunks run inline, in order.
        let inline: Result<Vec<usize>, String> =
            serial_scope(|| scatter_gather(&pool, &chunks, |r| Ok(r.start)));
        assert_eq!(inline.unwrap(), vec![0, 3, 6, 8]);
    }

    #[test]
    fn par_row_chunks_matches_serial() {
        let pool = ThreadPool::new(3);
        let rows = 17;
        let row_len = 5;
        let mut par = vec![0i32; rows * row_len];
        par_row_chunks_mut(&pool, &mut par, rows, row_len, 1, |first_row, block| {
            for (r, row) in block.chunks_mut(row_len).enumerate() {
                for (c, v) in row.iter_mut().enumerate() {
                    *v = ((first_row + r) * row_len + c) as i32;
                }
            }
        });
        let want: Vec<i32> = (0..(rows * row_len) as i32).collect();
        assert_eq!(par, want);
    }

    #[test]
    fn nested_parallelism_runs_inline() {
        let pool = ThreadPool::new(2);
        let hits = std::sync::atomic::AtomicUsize::new(0);
        let hits_ref = &hits;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                Box::new(move || {
                    // Inner scoped run from a worker thread must complete
                    // inline rather than deadlock on a saturated queue.
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                        .map(|_| {
                            Box::new(move || {
                                hits_ref.fetch_add(1, Ordering::SeqCst);
                            })
                                as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    ThreadPool::global().run_scoped(inner);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(hits.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn task_panic_payload_is_preserved() {
        // Regression: the pool used to re-raise worker panics as a fresh
        // `panic!("parallel task panicked")`, discarding the original
        // payload (and with it the actual failure message). The original
        // payload must survive the pool hop, typed.
        #[derive(Debug, PartialEq)]
        struct Marker(u32);
        let pool = ThreadPool::new(2);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| std::panic::panic_any(Marker(42))),
                Box::new(|| {}),
                Box::new(|| {}),
            ];
            pool.run_scoped(tasks);
        }));
        let payload = result.unwrap_err();
        let m = payload
            .downcast_ref::<Marker>()
            .expect("original panic payload, not a wrapper");
        assert_eq!(m, &Marker(42));
        // A panicking INLINE task (the last task runs on the caller)
        // also surfaces its own payload.
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| std::panic::panic_any(Marker(7))),
            ];
            pool.run_scoped(tasks);
        }));
        let payload = result.unwrap_err();
        assert_eq!(payload.downcast_ref::<Marker>(), Some(&Marker(7)));
    }

    #[test]
    fn lock_recover_recovers_a_poisoned_mutex() {
        let m = Mutex::new(7usize);
        let _ = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("die holding the lock");
        }));
        assert!(m.is_poisoned());
        // `lock().unwrap()` would now panic in every thread forever; the
        // recovering helper hands back the (structurally intact) data.
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn task_panic_propagates() {
        let pool = ThreadPool::new(2);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("boom")),
                Box::new(|| {}),
            ];
            pool.run_scoped(tasks);
        }));
        assert!(result.is_err());
        // Pool stays usable after a task panic.
        let ran = AtomicBool::new(false);
        let ran_ref = &ran;
        pool.run_scoped(vec![
            Box::new(move || ran_ref.store(true, Ordering::SeqCst)),
            Box::new(|| {}),
        ]);
        assert!(ran.load(Ordering::SeqCst));
    }
}
