//! Regenerates the paper's fig6_sigmoid_f16 pattern and benches it across all
//! inference environments (see DESIGN.md experiment index).
fn main() {
    pqdl::bench_util::fig::run_figure_bench(pqdl::figures::Figure::Fig6SigmoidF16);
}
