//! RESCALE experiment (paper §3.1): the integer-scale + right-shift
//! decomposition. Sweeps multipliers across the practical range,
//! reporting relative representation error (bounded by 2^-24 because
//! Quant_scale is capped at the largest exactly-representable f32
//! integer), verifies the paper's worked examples, and times the
//! integer rescale unit against the float path.

use pqdl::bench_util::{bench_auto, section};
use pqdl::quant::{apply_integer, decompose, RescaleDecomposition, MAX_EXACT_F32_INT};

fn main() {
    section("paper worked examples (§3.1)");
    let quarter = decompose(0.25, 31).unwrap();
    println!(
        "0.25      -> Quant_scale {:>8}, shift {:>2}  (exact: {})",
        quarter.quant_scale,
        quarter.shift,
        quarter.multiplier() == 0.25
    );
    let third = decompose(1.0 / 3.0, 31).unwrap();
    println!(
        "1/3       -> Quant_scale {:>8}, shift {:>2}  (paper: 11184810, 25; rel err {:.3e})",
        third.quant_scale,
        third.shift,
        third.relative_error(1.0 / 3.0)
    );
    println!("largest exactly-representable integer in FLOAT: {MAX_EXACT_F32_INT} = 2^24");

    section("decomposition error sweep (multipliers 2^-12 .. 2^4)");
    println!("multiplier   | quant_scale | shift | rel error");
    for e in (-12..=4).rev() {
        let m = (2.0_f32).powi(e) * 1.3; // off the power-of-two grid
        let d = decompose(m, 31).unwrap();
        println!(
            "{m:<12.6} | {:>11} | {:>5} | {:.3e}",
            d.quant_scale,
            d.shift,
            d.relative_error(m as f64)
        );
    }

    section("exactness over 10_000 f32 multipliers (unbounded shift)");
    // Stronger than the 2^-24 bound: an f32 multiplier has a 24-bit
    // significand, so whenever the shift budget is not the binding
    // constraint the decomposition reproduces it EXACTLY — the paper's
    // FLOAT-encoded Quant_scale loses nothing vs the f32 multiplier.
    let mut worst = 0f64;
    let mut worst_m = 0f32;
    for i in 1..=10_000 {
        let m = i as f32 * 1.7e-4;
        let d = decompose(m, 40).unwrap();
        let e = d.relative_error(m as f64);
        if e > worst {
            worst = e;
            worst_m = m;
        }
    }
    println!(
        "worst rel error {worst:.3e} at multiplier {worst_m} — f32 multipliers decompose exactly"
    );
    assert_eq!(worst, 0.0);

    section("shift-budget ablation: precision vs max right-shift bits");
    println!("max_shift | worst rel error (multipliers in [1e-4, 1])");
    for max_shift in [8u32, 12, 16, 20, 24, 31] {
        let mut worst = 0f64;
        for i in 1..=2000 {
            let m = i as f32 * 5e-4;
            if let Ok(d) = decompose(m, max_shift) {
                worst = worst.max(d.relative_error(m as f64));
            }
        }
        println!("{max_shift:>9} | {worst:.3e}");
    }

    section("rescale-unit timing: integer (mul+shift) vs float path");
    let d: RescaleDecomposition = decompose(1.0 / 3.0, 31).unwrap();
    let accs: Vec<i32> = (0..4096).map(|i| (i * 37 % 65536) - 32768).collect();
    let s1 = bench_auto("integer mul+shift (hw unit)", accs.len(), 200, {
        let accs = accs.clone();
        move || {
            let mut sum = 0i64;
            for &a in &accs {
                sum += apply_integer(a, &d, -128, 127) as i64;
            }
            std::hint::black_box(sum);
        }
    });
    println!("{}", s1.row());
    let qs = d.quant_scale_f32();
    let qh = d.quant_shift_f32();
    let s2 = bench_auto("float mul,mul + round (onnx path)", accs.len(), 200, {
        let accs = accs.clone();
        move || {
            let mut sum = 0i64;
            for &a in &accs {
                let f = a as f32 * qs * qh;
                sum += pqdl::ops::qlinear::round_half_even(f).clamp(-128.0, 127.0) as i64;
            }
            std::hint::black_box(sum);
        }
    });
    println!("{}", s2.row());

    section("integer vs float agreement over the full i32-accumulator span");
    let mut diffs = [0usize; 3];
    let mut checked = 0u64;
    for i in 0..200_000u64 {
        let acc = (i as i64 * 10_737 % (1 << 31)) as i32 - (1 << 30);
        let hw = apply_integer(acc, &d, -128, 127);
        let float =
            pqdl::ops::qlinear::round_half_even(acc as f32 * qs * qh).clamp(-128.0, 127.0) as i32;
        let delta = ((hw - float).unsigned_abs()).min(2) as usize;
        diffs[delta] += 1;
        checked += 1;
    }
    println!(
        "checked {checked}: exact {} ({:.4}%), 1 LSB {}, >1 LSB {}",
        diffs[0],
        100.0 * diffs[0] as f64 / checked as f64,
        diffs[1],
        diffs[2]
    );
}
