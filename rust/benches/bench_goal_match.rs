//! GOAL3 experiment: "closely matching output (within narrow margins)
//! on all inference environments" — the paper's goal 3, measured.
//!
//! Every Figure 1–6 pattern × {interp (reference), hwsim, pjrt} × 1000
//! random inputs: exact-match rate, ≤1-LSB rate, max LSB difference.
//! These are the headline rows of EXPERIMENTS.md.

use pqdl::bench_util::fig::backends_for;
use pqdl::bench_util::section;
use pqdl::coordinator::validate;
use pqdl::figures::Figure;
use pqdl::tensor::Tensor;

fn main() {
    let n_inputs = 125; // x batch 8 = 1000 samples per figure
    section(&format!(
        "cross-environment agreement, {} inputs x batch 8 per figure",
        n_inputs
    ));
    let mut all_ok = true;
    for fig in Figure::ALL {
        let backends = backends_for(fig);
        let inputs: Vec<Tensor> = (0..n_inputs).map(|s| fig.input(8, s as u64)).collect();
        let report = validate(fig.name(), &backends, &inputs).expect("validate");
        print!("{}", report.table());
        // Slope-amplified tolerance per figure (see DESIGN.md).
        let tol = match fig {
            Figure::Fig4TanhInt8 => 5,
            Figure::Fig5TanhF16 => 3,
            Figure::Fig6SigmoidF16 => 6,
            _ => 1,
        };
        let ok = report.all_within(tol);
        println!("--> within {tol} LSB everywhere: {ok}\n");
        all_ok &= ok;
    }
    println!(
        "GOAL3 verdict: {}",
        if all_ok {
            "PASS — all environments agree within narrow margins"
        } else {
            "FAIL"
        }
    );
    assert!(all_ok);
}
