//! Regenerates the paper's fig2_fc_relu pattern and benches it across all
//! inference environments (see DESIGN.md experiment index).
fn main() {
    pqdl::bench_util::fig::run_figure_bench(pqdl::figures::Figure::Fig2FcReluOneMul);
}
