//! CALIB experiment: the motivation of the paper's §3 — calibration
//! strategy is a *model-producer* decision, decoupled from the hardware
//! flow. Trains one fp32 MLP, quantizes it under each strategy, and
//! reports accuracy on interpreter and hardware simulator (which never
//! change).

use pqdl::bench_util::section;
use pqdl::hwsim::{HwConfig, HwModule};
use pqdl::interp::Session;
use pqdl::quant::CalibStrategy;
use pqdl::rewrite::{calibrate, quantize_model, QuantizeOptions};
use pqdl::tensor::Tensor;
use pqdl::train::{accuracy, synthetic_digits, train_classifier, HiddenAct, Mlp};

fn eval_acc(probs: &Tensor, data: &pqdl::train::Dataset) -> f32 {
    probs
        .as_f32()
        .unwrap()
        .chunks(10)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        })
        .zip(&data.y)
        .filter(|(p, y)| p == *y)
        .count() as f32
        / data.len() as f32
}

fn main() {
    let data = synthetic_digits(3000, 777);
    let (train, test) = data.split(0.2, 778);
    let mut mlp = Mlp::new(&[64, 64, 10], HiddenAct::Relu, 779);
    train_classifier(&mut mlp, &train, 25, 32, 0.1, 0.9, 780);
    let fp32_acc = accuracy(&mlp, &test);

    // Inject synthetic outliers into the calibration stream so the
    // strategies actually diverge (max-range is outlier-sensitive).
    let model = mlp.to_model("digits_mlp");
    let sess = Session::new(model.clone()).unwrap();
    let mut batches: Vec<Vec<(String, Tensor)>> = (0..128)
        .map(|i| {
            let (x, _) = train.sample(i);
            vec![("x".to_string(), Tensor::from_f32(&[1, 64], x.to_vec()).unwrap())]
        })
        .collect();
    // 2% of calibration samples carry a large spike.
    for i in (0..batches.len()).step_by(50) {
        let mut spiky = batches[i][0].1.as_f32().unwrap().to_vec();
        spiky[0] = 25.0;
        batches[i][0].1 = Tensor::from_f32(&[1, 64], spiky).unwrap();
    }

    let mut xs = Vec::with_capacity(test.len() * 64);
    for i in 0..test.len() {
        xs.extend_from_slice(test.sample(i).0);
    }
    let full = Tensor::from_f32(&[test.len(), 64], xs).unwrap();

    section(&format!(
        "calibration ablation (fp32 reference {:.2}%, calib stream has 2% spiky outliers)",
        100.0 * fp32_acc
    ));
    println!("strategy      | int8 interp acc | int8 hwsim acc | input scale");
    for strategy in [
        CalibStrategy::MaxRange,
        CalibStrategy::Percentile(0.999),
        CalibStrategy::Percentile(0.99),
        CalibStrategy::Mse,
    ] {
        let cal = calibrate(&sess, &batches, strategy).unwrap();
        let q = quantize_model(&model, &cal, &QuantizeOptions::default()).unwrap();
        let qsess = Session::new(q.clone()).unwrap();
        let probs = qsess.run(&[("x", full.clone())]).unwrap().remove(0);
        let interp_acc = eval_acc(&probs, &test);
        let hw = HwModule::compile(&q, HwConfig::default()).unwrap();
        let (hw_probs, _) = hw.run(&full).unwrap();
        let hw_acc = eval_acc(&hw_probs, &test);
        // Report the embedded input scale (first QuantizeLinear scale).
        let in_scale = q
            .graph
            .initializers
            .iter()
            .find(|(n, _)| n.contains("x_scale"))
            .map(|(_, t)| t.as_f32().unwrap()[0])
            .unwrap_or(f32::NAN);
        println!(
            "{:<13} | {:>14.2}% | {:>13.2}% | {:.5}",
            format!("{strategy:?}").chars().take(13).collect::<String>(),
            100.0 * interp_acc,
            100.0 * hw_acc,
            in_scale
        );
    }
    println!("\n(the executors and the model format were identical for every row)");
}
