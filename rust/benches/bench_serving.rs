//! E2E serving experiment: coordinator throughput/latency on the
//! quantized digits MLP as dynamic batching scales, closed-loop clients —
//! plus the serial-vs-parallel executor comparison on multi-row batches
//! (the acceptance measurement for the batch-parallel `Session::run`).

use pqdl::bench_util::{bench_auto, env_usize, section, JsonReport};
use pqdl::coordinator::{CoordinatorBuilder, InterpBackend, ServerConfig};
use pqdl::interp::{PlanOptions, Session};
use pqdl::parallel::ThreadPool;
use pqdl::quant::CalibStrategy;
use pqdl::rewrite::{calibrate, quantize_model, QuantizeOptions};
use pqdl::tensor::Tensor;
use pqdl::train::{synthetic_digits, train_classifier, HiddenAct, Mlp};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    // One trained + quantized model serves the whole bench.
    let data = synthetic_digits(2000, 31);
    let (train, _) = data.split(0.2, 32);
    let mut mlp = Mlp::new(&[64, 128, 64, 10], HiddenAct::Relu, 33);
    train_classifier(&mut mlp, &train, 10, 32, 0.08, 0.9, 34);
    let model = mlp.to_model("digits");
    let sess = Session::new(model.clone()).unwrap();
    let batches: Vec<_> = (0..64)
        .map(|i| {
            let (x, _) = train.sample(i);
            vec![("x".to_string(), Tensor::from_f32(&[1, 64], x.to_vec()).unwrap())]
        })
        .collect();
    let cal = calibrate(&sess, &batches, CalibStrategy::MaxRange).unwrap();
    let preq = quantize_model(&model, &cal, &QuantizeOptions::default()).unwrap();

    // --- serial vs parallel executor on multi-row batches ----------------
    let target_ms = env_usize("PQDL_BENCH_TARGET_MS", 150) as u64;
    let qsess = Session::new(preq.clone()).unwrap();
    // Machine-readable trajectory: PQDL_BENCH_JSON=<path> writes every
    // measured row (see EXPERIMENTS.md §Perf / BENCH_serving.json).
    let mut json = JsonReport::new("serving");
    section(&format!(
        "serial vs parallel Session::run on the quantized MLP ({} pool threads)",
        ThreadPool::global().threads()
    ));
    println!(
        "{:<8} | {:>14} | {:>14} | {:>8}",
        "batch", "serial itm/s", "parallel itm/s", "speedup"
    );
    let batch_of = |n: usize| {
        let mut xs = Vec::with_capacity(n * 64);
        for i in 0..n {
            xs.extend_from_slice(train.sample(i % train.len()).0);
        }
        Tensor::from_f32(&[n, 64], xs).unwrap()
    };
    for batch in [1usize, 8, 32, 128] {
        let x = batch_of(batch);
        let serial = {
            let x = x.clone();
            let s = &qsess;
            bench_auto(&format!("serial b{batch}"), batch, target_ms, move || {
                s.run_serial(&[("x", x.clone())]).expect("serial run");
            })
        };
        let parallel = {
            let x = x.clone();
            let s = &qsess;
            bench_auto(&format!("parallel b{batch}"), batch, target_ms, move || {
                s.run(&[("x", x.clone())]).expect("parallel run");
            })
        };
        println!(
            "{batch:<8} | {:>14.1} | {:>14.1} | {:>7.2}x",
            serial.throughput_per_s,
            parallel.throughput_per_s,
            parallel.throughput_per_s / serial.throughput_per_s
        );
        json.record(&format!("serial b{batch}"), batch, &serial);
        json.record(&format!("parallel b{batch}"), batch, &parallel);
    }

    // --- planned vs legacy interpreter, plus the recycled entry point ----
    // Same workloads, all strictly serial. NOTE on attribution: since the
    // scratch-planner PR, `run_serial` ("planned") ALREADY executes with
    // the arena-recycled buffers and packed int8 GEMM — so the arena +
    // packed win shows up as the change in the "planned" rows ACROSS
    // COMMITS (pre-PR vs post-PR BENCH_serving.json), not as a column in
    // one run. Within a run, "recycled" (`run_into` with handed-back
    // outputs and borrowed feeds) isolates only the last two per-call
    // allocations: the output tensors and the per-iteration feed clone.
    // `run_unplanned` IS the pre-plan interpreter, retained for exactly
    // this comparison and the bit-identity proptests.
    section("planned vs legacy interpreter (compile-once plans + scratch arena)");
    println!(
        "{:<8} | {:>14} | {:>14} | {:>14} | {:>8} | {:>8}",
        "batch", "legacy itm/s", "planned itm/s", "recycled itm/s", "plan x", "into x"
    );
    for batch in [1usize, 8, 32, 128] {
        let x = batch_of(batch);
        let legacy = {
            let x = x.clone();
            let s = &qsess;
            bench_auto(&format!("legacy b{batch}"), batch, target_ms, move || {
                s.run_unplanned(&[("x", x.clone())]).expect("legacy run");
            })
        };
        let planned = {
            let x = x.clone();
            let s = &qsess;
            bench_auto(&format!("planned b{batch}"), batch, target_ms, move || {
                s.run_serial(&[("x", x.clone())]).expect("planned run");
            })
        };
        let recycled = {
            let x = x.clone();
            let s = &qsess;
            let mut outs = Vec::new();
            bench_auto(&format!("recycled b{batch}"), batch, target_ms, move || {
                pqdl::parallel::serial_scope(|| {
                    s.run_into(&[("x", &x)], &mut outs).expect("recycled run");
                });
            })
        };
        println!(
            "{batch:<8} | {:>14.1} | {:>14.1} | {:>14.1} | {:>7.2}x | {:>7.2}x",
            legacy.throughput_per_s,
            planned.throughput_per_s,
            recycled.throughput_per_s,
            planned.throughput_per_s / legacy.throughput_per_s,
            recycled.throughput_per_s / legacy.throughput_per_s
        );
        json.record(&format!("legacy b{batch}"), batch, &legacy);
        json.record(&format!("planned b{batch}"), batch, &planned);
        json.record(&format!("recycled b{batch}"), batch, &recycled);
    }

    // --- fused vs unfused plan (plan-time graph optimizer) ----------------
    // `qsess` (the default session) executes the FUSED plan — its chains
    // collapse into FusedQFc kernels doing rescale+saturate in one pass.
    // The unfused session is the same model with `PlanOptions { fuse:
    // false }`: the pre-optimizer node-per-step plan, bit-identical by
    // the executor_plan differential contract. NOTE for cross-commit
    // attribution: the "planned" rows above ALSO run fused now — this
    // section isolates the fusion win within one run.
    let unfused_sess =
        Session::new_with_options(preq.clone(), PlanOptions { fuse: false }).unwrap();
    let pstats = qsess.plan_stats();
    section(&format!("fused vs unfused plan — {pstats}"));
    println!(
        "{:<8} | {:>14} | {:>14} | {:>8}",
        "batch", "unfused itm/s", "fused itm/s", "speedup"
    );
    for batch in [1usize, 8, 32, 128] {
        let x = batch_of(batch);
        let unfused = {
            let x = x.clone();
            let s = &unfused_sess;
            bench_auto(&format!("unfused b{batch}"), batch, target_ms, move || {
                s.run_serial(&[("x", x.clone())]).expect("unfused run");
            })
        };
        let fused = {
            let x = x.clone();
            let s = &qsess;
            bench_auto(&format!("fused b{batch}"), batch, target_ms, move || {
                s.run_serial(&[("x", x.clone())]).expect("fused run");
            })
        };
        println!(
            "{batch:<8} | {:>14.1} | {:>14.1} | {:>7.2}x",
            unfused.throughput_per_s,
            fused.throughput_per_s,
            fused.throughput_per_s / unfused.throughput_per_s
        );
        json.record(&format!("unfused b{batch}"), batch, &unfused);
        json.record(&format!("fused b{batch}"), batch, &fused);
    }

    // --- per-ISA microkernel rows (plan-time kernel dispatch) -------------
    // The same packed int8 GEMM and fused FC kernel, forced through every
    // ISA variant this host supports (scalar always present — it is the
    // differential oracle, so these rows double as a sanity check that
    // the variants measure the same work). Rows land in the JSON
    // trajectory so BENCH_serving.json can compare ISA lanes across
    // commits; `PQDL_FORCE_ISA` pins an entire serving run instead.
    {
        use pqdl::ops::bitpack::PackedWeights;
        use pqdl::ops::fused::{ActPack, FusedQFc, QEpilogue};
        use pqdl::ops::matmul::{self, PackedB};
        use pqdl::ops::Isa;
        use pqdl::quant::QType;
        use pqdl::train::Rng;

        let (k, n) = (64usize, 128usize);
        let mut rng = Rng::new(0x15A);
        let bw: Vec<i32> = (0..k * n).map(|_| rng.i8() as i32).collect();
        let bp = PackedB::pack(&bw, k, n).expect("i8-ranged weights must pack");
        let bias: Vec<i32> = (0..n).map(|j| j as i32 * 7 - 400).collect();
        section(&format!(
            "per-ISA packed GEMM + fused FC (k={k}, n={n}; plan default: {})",
            Isa::active()
        ));
        println!(
            "{:<8} | {:<8} | {:>14} | {:>14}",
            "isa", "batch", "gemm itm/s", "fused itm/s"
        );
        for batch in [8usize, 128] {
            let a: Vec<i8> = (0..batch * k).map(|_| rng.i8()).collect();
            let x = Tensor::from_i8(&[batch, k], a.clone()).unwrap();
            for isa in Isa::available() {
                let gemm = {
                    let a = &a;
                    let bp = &bp;
                    let mut c = vec![0i32; batch * n];
                    bench_auto(&format!("isa {isa} gemm b{batch}"), batch, target_ms, move || {
                        matmul::gemm_i8_packed_isa(isa, a, bp, batch, &mut c);
                    })
                };
                let fc = FusedQFc {
                    bw: bw.clone(),
                    bp: PackedB::pack(&bw, k, n).map(PackedWeights::I8),
                    k,
                    n,
                    a_zp: 0,
                    bias: Some(bias.clone()),
                    isa,
                    epi: QEpilogue {
                        s1: 0.013,
                        s2: None,
                        relu: true,
                        inv_scale: 1.0 / 0.11,
                        zp: 3,
                        out_qtype: QType::I8,
                    },
                    emit: ActPack::Container,
                    a_pack: ActPack::Container,
                };
                let fused = {
                    let x = x.clone();
                    let mut scratch = [None, None, None];
                    bench_auto(&format!("isa {isa} fc b{batch}"), batch, target_ms, move || {
                        fc.run(&x, None, &mut scratch).expect("fused fc run");
                    })
                };
                println!(
                    "{:<8} | {batch:<8} | {:>14.1} | {:>14.1}",
                    isa.name(),
                    gemm.throughput_per_s,
                    fused.throughput_per_s
                );
                json.record(&format!("isa {isa} gemm b{batch}"), batch, &gemm);
                json.record(&format!("isa {isa} fc b{batch}"), batch, &fused);
            }
        }

        // Narrow GEMM bodies per ISA: the nibble-activation int4 kernel
        // (packed-u8 A rows against widened i32 B) and the XNOR-popcount
        // bipolar kernel, each forced through every ISA this host
        // supports — scalar doubles as the differential oracle.
        {
            use pqdl::ops::bitpack::{
                gemm_i4a_bytes_isa, gemm_xnor_isa, pack_bits_rows, pack_nibble_rows, BitPackedB,
            };

            let bw4: Vec<i32> = (0..k * n).map(|_| rng.below(16) as i32 - 8).collect();
            let bw1: Vec<i32> = (0..k * n)
                .map(|_| if rng.below(2) == 0 { -1 } else { 1 })
                .collect();
            let bb = BitPackedB::pack(&bw1, k, n).expect("±1 weights must bit-pack");
            println!(
                "{:<8} | {:<8} | {:>14} | {:>14}",
                "isa", "batch", "i4a itm/s", "xnor itm/s"
            );
            for batch in [8usize, 128] {
                let a4: Vec<i8> = (0..batch * k).map(|_| rng.below(16) as i8 - 8).collect();
                let a1: Vec<i8> = (0..batch * k)
                    .map(|_| if rng.below(2) == 0 { -1i8 } else { 1 })
                    .collect();
                let mut a_bytes = Vec::new();
                pack_nibble_rows(&a4, batch, k, &mut a_bytes);
                let mut abits = Vec::new();
                assert!(pack_bits_rows(&a1, batch, k, &mut abits));
                for isa in Isa::available() {
                    let i4a = {
                        let a_bytes = &a_bytes;
                        let bw4 = &bw4;
                        let mut c = vec![0i32; batch * n];
                        bench_auto(&format!("isa {isa} i4a b{batch}"), batch, target_ms, move || {
                            gemm_i4a_bytes_isa(isa, a_bytes, batch, k, bw4, n, &mut c);
                        })
                    };
                    let xnor = {
                        let abits = &abits;
                        let bb = &bb;
                        let mut c = vec![0i32; batch * n];
                        bench_auto(&format!("isa {isa} xnor b{batch}"), batch, target_ms, move || {
                            gemm_xnor_isa(isa, abits, bb, batch, &mut c);
                        })
                    };
                    println!(
                        "{:<8} | {batch:<8} | {:>14.1} | {:>14.1}",
                        isa.name(),
                        i4a.throughput_per_s,
                        xnor.throughput_per_s
                    );
                    json.record(&format!("isa {isa} i4a b{batch}"), batch, &i4a);
                    json.record(&format!("isa {isa} xnor b{batch}"), batch, &xnor);
                }
            }
        }
    }

    // --- per-width microkernel rows (sub-8-bit weight packing) ------------
    // The same (k, n) GEMM + fused FC workload at each logical weight
    // width the planner can bake: full i8 panels, nibble-packed int4,
    // tribble int3, crumb int2, and XNOR-popcount bipolar (±1
    // activations, so the bit-sliced path runs for real rather than
    // falling back to the widened loop). Every width computes with the
    // same i32 accumulator semantics — these rows measure the packing's
    // memory/throughput effect, and land in the JSON trajectory so
    // per-width lanes compare across commits.
    {
        use pqdl::ops::bitpack::{
            gemm_i2_packed_isa, gemm_i3_packed_isa, gemm_i4_packed_isa, gemm_xnor_isa,
            pack_bits_rows, BitPackedB, PackedB2, PackedB3, PackedB4, PackedWeights,
        };
        use pqdl::ops::fused::{ActPack, FusedQFc, QEpilogue};
        use pqdl::ops::matmul::{self, PackedB};
        use pqdl::ops::Isa;
        use pqdl::quant::QType;
        use pqdl::train::Rng;

        let (k, n) = (64usize, 128usize);
        let mut rng = Rng::new(0x4B17);
        let bw8: Vec<i32> = (0..k * n).map(|_| rng.i8() as i32).collect();
        let bw4: Vec<i32> = (0..k * n).map(|_| rng.below(16) as i32 - 8).collect();
        let bw3: Vec<i32> = (0..k * n).map(|_| rng.below(8) as i32 - 4).collect();
        let bw2: Vec<i32> = (0..k * n).map(|_| rng.below(4) as i32 - 2).collect();
        let bw1: Vec<i32> = (0..k * n)
            .map(|_| if rng.below(2) == 0 { -1 } else { 1 })
            .collect();
        let isa = Isa::active();
        let packs = [
            ("int8", &bw8, PackedWeights::I8(PackedB::pack(&bw8, k, n).unwrap())),
            ("int4", &bw4, PackedWeights::I4(PackedB4::pack(&bw4, k, n).unwrap())),
            ("int3", &bw3, PackedWeights::I3(PackedB3::pack(&bw3, k, n).unwrap())),
            ("int2", &bw2, PackedWeights::I2(PackedB2::pack(&bw2, k, n).unwrap())),
            (
                "bipolar",
                &bw1,
                PackedWeights::Bipolar(BitPackedB::pack(&bw1, k, n).unwrap()),
            ),
        ];
        section(&format!(
            "per-width packed GEMM + fused FC (k={k}, n={n}, isa {isa})"
        ));
        println!(
            "{:<8} | {:<8} | {:>12} | {:>14} | {:>14}",
            "width", "batch", "baked bytes", "gemm itm/s", "fused itm/s"
        );
        for batch in [8usize, 128] {
            // ±1 activations: valid i8 input for every width, and the
            // alphabet the XNOR kernel's row bit-pack requires.
            let a: Vec<i8> = (0..batch * k)
                .map(|_| if rng.below(2) == 0 { -1i8 } else { 1 })
                .collect();
            let x = Tensor::from_i8(&[batch, k], a.clone()).unwrap();
            for (label, bw, pw) in &packs {
                let gemm = {
                    let a = &a;
                    let mut c = vec![0i32; batch * n];
                    let mut abits = Vec::new();
                    assert!(pack_bits_rows(a, batch, k, &mut abits));
                    bench_auto(
                        &format!("width {label} gemm b{batch}"),
                        batch,
                        target_ms,
                        move || match pw {
                            PackedWeights::I8(bp) => {
                                matmul::gemm_i8_packed_isa(isa, a, bp, batch, &mut c)
                            }
                            PackedWeights::I4(bp) => gemm_i4_packed_isa(isa, a, bp, batch, &mut c),
                            PackedWeights::I3(bp) => gemm_i3_packed_isa(isa, a, bp, batch, &mut c),
                            PackedWeights::I2(bp) => gemm_i2_packed_isa(isa, a, bp, batch, &mut c),
                            PackedWeights::Bipolar(bb) => {
                                gemm_xnor_isa(isa, &abits, bb, batch, &mut c)
                            }
                        },
                    )
                };
                // PackedWeights owns its panels (no Clone) — repack for
                // the fused kernel's copy.
                let fc_bp = match pw {
                    PackedWeights::I8(_) => PackedWeights::I8(PackedB::pack(bw, k, n).unwrap()),
                    PackedWeights::I4(_) => PackedWeights::I4(PackedB4::pack(bw, k, n).unwrap()),
                    PackedWeights::I3(_) => PackedWeights::I3(PackedB3::pack(bw, k, n).unwrap()),
                    PackedWeights::I2(_) => PackedWeights::I2(PackedB2::pack(bw, k, n).unwrap()),
                    PackedWeights::Bipolar(_) => {
                        PackedWeights::Bipolar(BitPackedB::pack(bw, k, n).unwrap())
                    }
                };
                let fc = FusedQFc {
                    bw: (*bw).clone(),
                    bp: Some(fc_bp),
                    k,
                    n,
                    a_zp: 0,
                    bias: None,
                    isa,
                    epi: QEpilogue {
                        s1: 0.013,
                        s2: None,
                        relu: true,
                        inv_scale: 1.0 / 0.11,
                        zp: 3,
                        out_qtype: QType::I8,
                    },
                    emit: ActPack::Container,
                    a_pack: ActPack::Container,
                };
                let fused = {
                    let x = x.clone();
                    let mut scratch = [None, None, None];
                    bench_auto(
                        &format!("width {label} fc b{batch}"),
                        batch,
                        target_ms,
                        move || {
                            fc.run(&x, None, &mut scratch).expect("fused fc run");
                        },
                    )
                };
                println!(
                    "{label:<8} | {batch:<8} | {:>12} | {:>14.1} | {:>14.1}",
                    pw.bytes(),
                    gemm.throughput_per_s,
                    fused.throughput_per_s
                );
                json.record(&format!("width {label} gemm b{batch}"), batch, &gemm);
                json.record(&format!("width {label} fc b{batch}"), batch, &fused);
            }
        }

        // Packed-activation vs container-activation fused FC: the same
        // int4-weight consumer fed (a) the plain i8 container edge and
        // (b) the nibble-packed u8 edge a paired producer hands it when
        // the planner stamps `a_pack: Nibble` — isolating the win of
        // skipping the unpack/repack round-trip between fused stages.
        {
            use pqdl::ops::bitpack::pack_nibble_rows;

            println!(
                "{:<10} | {:<8} | {:>14} | {:>8}",
                "a-edge", "batch", "fc itm/s", "speedup"
            );
            for batch in [8usize, 128] {
                let a: Vec<i8> = (0..batch * k).map(|_| rng.below(16) as i8 - 8).collect();
                let x_cont = Tensor::from_i8(&[batch, k], a.clone()).unwrap();
                let mut packed = Vec::new();
                pack_nibble_rows(&a, batch, k, &mut packed);
                let x_pack = Tensor::from_u8(&[batch, k.div_ceil(2)], packed).unwrap();
                let mk_fc = |a_pack: ActPack| FusedQFc {
                    bw: bw4.clone(),
                    bp: PackedB4::pack(&bw4, k, n).map(PackedWeights::I4),
                    k,
                    n,
                    a_zp: 0,
                    bias: None,
                    isa,
                    epi: QEpilogue {
                        s1: 0.013,
                        s2: None,
                        relu: true,
                        inv_scale: 1.0 / 0.11,
                        zp: 3,
                        out_qtype: QType::I8,
                    },
                    emit: ActPack::Container,
                    a_pack,
                };
                let cont = {
                    let fc = mk_fc(ActPack::Container);
                    let x = x_cont.clone();
                    let mut scratch = [None, None, None];
                    bench_auto(&format!("act cont fc b{batch}"), batch, target_ms, move || {
                        fc.run(&x, None, &mut scratch).expect("container-edge fc run");
                    })
                };
                let pack = {
                    let fc = mk_fc(ActPack::Nibble);
                    let x = x_pack.clone();
                    let mut scratch = [None, None, None];
                    bench_auto(&format!("act nibble fc b{batch}"), batch, target_ms, move || {
                        fc.run(&x, None, &mut scratch).expect("nibble-edge fc run");
                    })
                };
                println!(
                    "{:<10} | {batch:<8} | {:>14.1} | {:>8}",
                    "container", cont.throughput_per_s, ""
                );
                println!(
                    "{:<10} | {batch:<8} | {:>14.1} | {:>7.2}x",
                    "nibble",
                    pack.throughput_per_s,
                    pack.throughput_per_s / cont.throughput_per_s
                );
                json.record(&format!("act cont fc b{batch}"), batch, &cont);
                json.record(&format!("act nibble fc b{batch}"), batch, &pack);
            }
        }
    }

    // --- tuned vs default GEMM tile (plan-time micro-tuner) ---------------
    // The micro-tuner measures its winner for this machine fresh (own
    // in-memory cache, so the bench never inherits a stale winner), then
    // both configs run the same packed GEMM and fused FC workload. The
    // incumbent default competes in the tuner's shortlist, so tuned can
    // at worst tie it.
    {
        use pqdl::ops::bitpack::PackedWeights;
        use pqdl::ops::fused::{ActPack, FusedQFc, QEpilogue};
        use pqdl::ops::matmul::{self, PackedB};
        use pqdl::ops::Isa;
        use pqdl::quant::QType;
        use pqdl::train::Rng;
        use pqdl::tune::tuner::tune_gemms_with;
        use pqdl::tune::{GemmConfig, GemmProblem, ProblemKind, TuneCache, TuneMode};

        let (k, n) = (64usize, 128usize);
        let mut rng = Rng::new(0x7E5);
        let bw: Vec<i32> = (0..k * n).map(|_| rng.i8() as i32).collect();
        let isa = Isa::active();
        let cache = TuneCache::new(None);
        let problems = [GemmProblem {
            w: &bw,
            k,
            out: n,
            kind: ProblemKind::PackedBGemm,
            bits: 8,
        }];
        let outcome = tune_gemms_with(
            &cache,
            0xBE7C4,
            &problems,
            isa,
            ThreadPool::global().threads(),
            TuneMode::Full,
        );
        let tuned_cfg = outcome.cfg;
        section(&format!(
            "tuned vs default GEMM tile (k={k}, n={n}, isa {isa}; winner {tuned_cfg})"
        ));
        println!(
            "{:<8} | {:<26} | {:>14} | {:>14}",
            "batch", "config", "gemm itm/s", "fc itm/s"
        );
        for batch in [8usize, 128] {
            let a: Vec<i8> = (0..batch * k).map(|_| rng.i8()).collect();
            let x = Tensor::from_i8(&[batch, k], a.clone()).unwrap();
            for (label, cfg) in [("default", GemmConfig::DEFAULT), ("tuned", tuned_cfg)] {
                let bp = PackedB::pack_with(&bw, k, n, cfg).expect("i8-ranged weights must pack");
                let gemm = {
                    let a = &a;
                    let bp = &bp;
                    let mut c = vec![0i32; batch * n];
                    bench_auto(&format!("{label} gemm b{batch}"), batch, target_ms, move || {
                        matmul::gemm_i8_packed_par_isa(
                            ThreadPool::global(),
                            isa,
                            a,
                            bp,
                            batch,
                            &mut c,
                        );
                    })
                };
                let fc = FusedQFc {
                    bw: bw.clone(),
                    bp: PackedB::pack_with(&bw, k, n, cfg).map(PackedWeights::I8),
                    k,
                    n,
                    a_zp: 0,
                    bias: None,
                    isa,
                    epi: QEpilogue {
                        s1: 0.013,
                        s2: None,
                        relu: true,
                        inv_scale: 1.0 / 0.11,
                        zp: 3,
                        out_qtype: QType::I8,
                    },
                    emit: ActPack::Container,
                    a_pack: ActPack::Container,
                };
                let fused = {
                    let x = x.clone();
                    let mut scratch = [None, None, None];
                    bench_auto(&format!("{label} fc b{batch}"), batch, target_ms, move || {
                        fc.run(&x, None, &mut scratch).expect("fused fc run");
                    })
                };
                println!(
                    "{batch:<8} | {:<26} | {:>14.1} | {:>14.1}",
                    format!("{label} ({cfg})"),
                    gemm.throughput_per_s,
                    fused.throughput_per_s
                );
                json.record(&format!("{label} gemm b{batch}"), batch, &gemm);
                json.record(&format!("{label} fc b{batch}"), batch, &fused);
            }
        }
    }

    // --- plan memory: lazy unfused twin -----------------------------------
    // A pure-serving fused session carries ONE plan's baked weights; the
    // first observer/profiling use compiles the unfused twin and pays the
    // second copy. Both sizes land in the JSON trajectory so plan-memory
    // regressions show up across commits.
    {
        let serving = Session::new(preq.clone()).unwrap();
        let lean = serving.baked_plan_bytes();
        let twin_before = serving.plan_stats().twin_compiled;
        serving
            .run_observed(&[("x", batch_of(1))], &mut |_, _| {})
            .expect("observed run");
        let full = serving.baked_plan_bytes();
        section("plan memory — lazy unfused twin");
        println!(
            "serving-only: {lean} baked bytes (twin compiled: {twin_before}) | \
             after first observed run: {full} baked bytes (twin compiled: {})",
            serving.plan_stats().twin_compiled
        );
        json.record_raw("plan bytes serving", 1, lean as f64, 0.0, 0.0);
        json.record_raw("plan bytes +twin", 1, full as f64, 0.0, 0.0);
    }

    section("dynamic batching sweep (16 closed-loop clients x 150 reqs)");
    println!(
        "{:<28} | {:>9} | {:>10} | {:>8} | {:>8} | {:>8}",
        "config", "req/s", "mean batch", "p50 us", "p95 us", "p99 us"
    );
    for (max_batch, wait_us) in [
        (1usize, 1u64),
        (2, 100),
        (4, 100),
        (8, 200),
        (16, 200),
        (32, 500),
    ] {
        let coord = Arc::new(
            CoordinatorBuilder::new(ServerConfig {
                max_batch,
                max_wait: Duration::from_micros(wait_us),
                // One replica so the sweep isolates the batching policy.
                replicas: 1,
                ..ServerConfig::default()
            })
            .register("digits", Arc::new(InterpBackend::new(preq.clone()).unwrap()))
            .start(),
        );
        let n_clients = 16;
        let per_client = 150;
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..n_clients {
            let coord = coord.clone();
            let train = train.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..per_client {
                    let (x, _) = train.sample((c * per_client + i) % train.len());
                    let t = Tensor::from_f32(&[1, 64], x.to_vec()).unwrap();
                    coord.infer("digits", t).unwrap().output.unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let elapsed = t0.elapsed();
        let stats = coord.metrics.snapshot("digits").unwrap();
        println!(
            "{:<28} | {:>9.0} | {:>10.2} | {:>8} | {:>8} | {:>8}",
            format!("max_batch {max_batch}, wait {wait_us}us"),
            (n_clients * per_client) as f64 / elapsed.as_secs_f64(),
            stats.mean_batch(),
            stats.e2e.quantile_us(0.50),
            stats.e2e.quantile_us(0.95),
            stats.e2e.quantile_us(0.99),
        );
        coord.shutdown();
    }

    // --- replica sweep: same closed-loop load, scaling lane replicas -----
    // Replicas share ONE compiled plan (Session::fork_replica); the sweep
    // shows the pool soaking up concurrency the single-worker lane
    // serialized. Closed-loop: req/s is the end-to-end acceptance number.
    section("replica sweep (16 closed-loop clients x 200 reqs, max_batch 8, wait 200us)");
    println!(
        "{:<12} | {:>9} | {:>10} | {:>10} | {:>8} | {:>8}",
        "replicas", "req/s", "mean reqs", "mean rows", "p50 us", "p99 us"
    );
    let mut replica_rps: Vec<(usize, f64)> = Vec::new();
    for replicas in [1usize, 2, 4, 8] {
        let coord = Arc::new(
            CoordinatorBuilder::new(ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(200),
                replicas,
                ..ServerConfig::default()
            })
            .register("digits", Arc::new(InterpBackend::new(preq.clone()).unwrap()))
            .start(),
        );
        let n_clients = 16;
        let per_client = 200;
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..n_clients {
            let coord = coord.clone();
            let train = train.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..per_client {
                    let (x, _) = train.sample((c * per_client + i) % train.len());
                    let t = Tensor::from_f32(&[1, 64], x.to_vec()).unwrap();
                    coord.infer("digits", t).unwrap().output.unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let elapsed = t0.elapsed();
        let rps = (n_clients * per_client) as f64 / elapsed.as_secs_f64();
        let stats = coord.metrics.snapshot("digits").unwrap();
        println!(
            "{replicas:<12} | {rps:>9.0} | {:>10.2} | {:>10.2} | {:>8} | {:>8}",
            stats.mean_batch(),
            stats.mean_rows(),
            stats.e2e.quantile_us(0.50),
            stats.e2e.quantile_us(0.99),
        );
        json.record_raw(
            &format!("replicas {replicas}"),
            n_clients * per_client,
            rps,
            stats.e2e.quantile_us(0.50) as f64,
            stats.e2e.quantile_us(0.99) as f64,
        );
        replica_rps.push((replicas, rps));
        coord.shutdown();
    }
    if let (Some((_, r1)), Some((_, r4))) = (
        replica_rps.iter().find(|(r, _)| *r == 1),
        replica_rps.iter().find(|(r, _)| *r == 4),
    ) {
        println!("replicas=4 vs replicas=1 speedup: {:.2}x", r4 / r1);
    }

    // --- saturation: open-loop burst against a bounded queue --------------
    // Admission control under overload: a burst far past queue_depth must
    // be shed with QueueFull (never queued unboundedly), accepted work
    // still completes, and the shed rate is reported per configuration.
    section("saturation burst (open-loop 4000-request burst, queue_depth 128, deadline 50ms)");
    println!(
        "{:<12} | {:>9} | {:>9} | {:>10} | {:>10} | {:>9}",
        "replicas", "ok", "shed", "queue-full", "deadline", "shed rate"
    );
    for replicas in [1usize, 4] {
        let coord = Arc::new(
            CoordinatorBuilder::new(ServerConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(200),
                replicas,
                queue_depth: 128,
                deadline: Some(Duration::from_millis(50)),
                controller: None,
            })
            .register("digits", Arc::new(InterpBackend::new(preq.clone()).unwrap()))
            .start(),
        );
        let burst = 4000;
        let mut rxs = Vec::with_capacity(burst);
        for i in 0..burst {
            let (x, _) = train.sample(i % train.len());
            let t = Tensor::from_f32(&[1, 64], x.to_vec()).unwrap();
            rxs.push(coord.submit("digits", t).unwrap());
        }
        let mut ok = 0usize;
        let mut shed = 0usize;
        for rx in rxs {
            let resp = rx.recv().expect("every request gets one response");
            if resp.output.is_ok() {
                ok += 1;
            } else {
                shed += 1;
            }
        }
        let stats = coord.metrics.snapshot("digits").unwrap();
        println!(
            "{replicas:<12} | {ok:>9} | {shed:>9} | {:>10} | {:>10} | {:>8.1}%",
            stats.shed_queue_full,
            stats.shed_deadline,
            100.0 * stats.shed_rate(),
        );
        json.record_raw(
            &format!("saturation r{replicas} shed_rate_pct"),
            burst,
            100.0 * stats.shed_rate(),
            stats.shed_queue_full as f64,
            stats.shed_deadline as f64,
        );
        coord.shutdown();
    }

    json.flush();
}
