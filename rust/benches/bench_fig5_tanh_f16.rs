//! Regenerates the paper's fig5_tanh_f16 pattern and benches it across all
//! inference environments (see DESIGN.md experiment index).
fn main() {
    pqdl::bench_util::fig::run_figure_bench(pqdl::figures::Figure::Fig5TanhF16);
}
