//! CODESIGN experiment: one pre-quantized model file, many hardware
//! points. MAC-array size vs cycles/energy/utilization on the Fig. 3
//! conv pattern, and activation-ROM width vs accuracy-critical LUT
//! fidelity on the Fig. 4 tanh pattern — the quantitative form of the
//! paper's co-design claim.

use pqdl::bench_util::section;
use pqdl::coordinator::{validate, Backend, HwSimBackend, InterpBackend};
use pqdl::figures::Figure;
use pqdl::hwsim::{HwConfig, HwModule, Rounding};
use pqdl::tensor::Tensor;
use std::sync::Arc;

fn main() {
    // --- MAC array sweep on the conv pattern (Fig. 3) -------------------
    let fig = Figure::Fig3Conv;
    let model = fig.model();
    let x = fig.input(16, 99);
    section("MAC-array sweep on fig3_conv, batch 16 (one model file)");
    println!("array   | cycles | ideal-cycles | utilization | energy nJ");
    for dim in [4usize, 8, 16, 32, 64, 128] {
        let cfg = HwConfig::default().with_array(dim, dim);
        let hw = HwModule::compile(&model, cfg.clone()).unwrap();
        let (_, cost) = hw.run(&x).unwrap();
        let ideal = cost.macs as f64 / (dim * dim) as f64;
        println!(
            "{dim:>3}x{dim:<3} | {:>6} | {:>12.0} | {:>10.1}% | {:>9.1}",
            cost.cycles,
            ideal,
            100.0 * cost.utilization(&cfg),
            cost.energy_nj(&cfg)
        );
    }

    // --- LUT width: fidelity of the activation stage (Fig. 4) -----------
    let fig = Figure::Fig4TanhInt8;
    let model = fig.model();
    section("activation-ROM width on fig4_tanh_int8: agreement vs standard tools");
    println!("lut bits | exact%   | <=1 LSB% | max LSB diff");
    let inputs: Vec<Tensor> = (0..40).map(|s| fig.input(8, s)).collect();
    for bits in [8u32, 7, 6, 5, 4, 3] {
        let backends: Vec<Arc<dyn Backend>> = vec![
            Arc::new(InterpBackend::new(model.clone()).unwrap()),
            Arc::new(
                HwSimBackend::new(&model, HwConfig::default().with_lut_bits(bits)).unwrap(),
            ),
        ];
        let rep = validate(fig.name(), &backends, &inputs).unwrap();
        let r = &rep.rows[0].report;
        println!(
            "{bits:>8} | {:>7.3}% | {:>7.3}% | {:>12}",
            100.0 * r.exact_rate(),
            100.0 * r.within(1),
            r.max_abs_diff
        );
    }

    // --- Rounding hardware: fidelity of the rescale unit (Fig. 1) -------
    let fig = Figure::Fig1FcTwoMul;
    let model = fig.model();
    section("rescale rounding mode on fig1_fc: agreement vs standard tools");
    println!("rounding      | exact%   | <=1 LSB% | max LSB diff");
    let inputs: Vec<Tensor> = (0..40).map(|s| fig.input(8, s)).collect();
    for (name, r) in [
        ("half-even   ", Rounding::HalfEven),
        ("half-away-0 ", Rounding::HalfAwayFromZero),
        ("truncate    ", Rounding::Truncate),
    ] {
        let backends: Vec<Arc<dyn Backend>> = vec![
            Arc::new(InterpBackend::new(model.clone()).unwrap()),
            Arc::new(HwSimBackend::new(&model, HwConfig::default().with_rounding(r)).unwrap()),
        ];
        let rep = validate(fig.name(), &backends, &inputs).unwrap();
        let rr = &rep.rows[0].report;
        println!(
            "{name} | {:>7.3}% | {:>7.3}% | {:>12}",
            100.0 * rr.exact_rate(),
            100.0 * rr.within(1),
            rr.max_abs_diff
        );
    }
}
