//! Quickstart: train a tiny fp32 MLP, quantize it into the paper's
//! pre-quantized ONNX form, and run the SAME model file on the generic
//! interpreter and the integer-only hardware simulator.
//!
//!     cargo run --release --example quickstart

use pqdl::hwsim::{HwConfig, HwModule};
use pqdl::interp::Session;
use pqdl::onnx::{model_from_json, model_to_json};
use pqdl::quant::CalibStrategy;
use pqdl::rewrite::{calibrate, quantize_model, QuantizeOptions};
use pqdl::tensor::Tensor;
use pqdl::train::{accuracy, synthetic_digits, train_classifier, HiddenAct, Mlp};

fn main() -> anyhow::Result<()> {
    // 1. Train a small fp32 model on a real (synthetic) workload.
    let data = synthetic_digits(1200, 7);
    let (train, test) = data.split(0.2, 8);
    let mut mlp = Mlp::new(&[64, 32, 10], HiddenAct::Relu, 9);
    println!("training fp32 MLP ({} params)...", mlp.param_count());
    train_classifier(&mut mlp, &train, 20, 32, 0.1, 0.9, 10);
    let fp32_acc = accuracy(&mlp, &test);
    println!("fp32 test accuracy: {:.1}%", 100.0 * fp32_acc);

    // 2. Export to ONNX form and calibrate on training data.
    let model = mlp.to_model("quickstart_mlp");
    let sess = Session::new(model.clone())?;
    let batches: Vec<_> = (0..64)
        .map(|i| {
            let (x, _) = train.sample(i);
            vec![("x".to_string(), Tensor::from_f32(&[1, 64], x.to_vec()).unwrap())]
        })
        .collect();
    let cal = calibrate(&sess, &batches, CalibStrategy::MaxRange)?;

    // 3. Rewrite into the pre-quantized patterns (Fig. 2 here: FC+ReLU),
    //    embedding Quant_scale / Quant_shift as initializers (2-Mul form).
    let preq = quantize_model(&model, &cal, &QuantizeOptions::default())?;
    let text = model_to_json(&preq);
    println!(
        "\npre-quantized model: {} nodes, {} bytes serialized, ops = {:?}",
        preq.graph.nodes.len(),
        text.len(),
        preq.graph
            .nodes
            .iter()
            .map(|n| n.op_type.as_str())
            .collect::<Vec<_>>()
    );

    // 4. The serialized file is the interchange: reload and execute it
    //    on both environments.
    let reloaded = model_from_json(&text)?;
    let qsess = Session::new(reloaded.clone())?;
    let hw = HwModule::compile(&reloaded, HwConfig::default())?;

    let (x0, label) = test.sample(0);
    let input = Tensor::from_f32(&[1, 64], x0.to_vec())?;
    let interp_out = qsess.run(&[("x", input.clone())])?;
    let (hw_out, cost) = hw.run(&input)?;

    let probs_i = interp_out[0].as_f32()?;
    let probs_h = hw_out.as_f32()?;
    let argmax = |p: &[f32]| {
        p.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    println!("\nsample 0 (true label {label}):");
    println!("  interpreter predicts {} ", argmax(probs_i));
    println!("  hw simulator predicts {}", argmax(probs_h));
    let max_diff = probs_i
        .iter()
        .zip(probs_h)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("  max |interp - hwsim| prob diff: {max_diff:.6}");
    println!(
        "  hw cost: {} MACs, {} cycles, {:.2} uJ, {:.1}% MAC utilization",
        cost.macs,
        cost.cycles,
        cost.energy_nj(&HwConfig::default()) / 1000.0,
        100.0 * cost.utilization(&HwConfig::default())
    );
    Ok(())
}
