//! Hardware/software co-design sweep (the CODESIGN experiment): one
//! pre-quantized CNN model file, many hardware configurations. The model
//! never changes — that is the paper's point — while MAC-array size, LUT
//! width and rounding mode trade accuracy against cycles and energy.
//!
//!     cargo run --release --example cnn_codesign

use pqdl::hwsim::{HwConfig, HwModule, Rounding};
use pqdl::interp::Session;
use pqdl::quant::CalibStrategy;
use pqdl::rewrite::{calibrate, quantize_model, QuantizeOptions};
use pqdl::tensor::Tensor;
use pqdl::train::{cnn_accuracy, synthetic_digits, train_cnn, Cnn};

fn main() -> anyhow::Result<()> {
    // Train the fp32 CNN once.
    let data = synthetic_digits(2500, 555);
    let (train, test) = data.split(0.2, 556);
    let mut cnn = Cnn::new(8, 10, 557);
    println!("training fp32 CNN ({} params)...", cnn.param_count());
    train_cnn(&mut cnn, &train, 12, 32, 0.08, 0.9, 558);
    let fp32_acc = cnn_accuracy(&cnn, &test);
    println!("fp32 test accuracy: {:.2}%\n", 100.0 * fp32_acc);

    // Quantize once: ONE model file for every hardware point below.
    let model = cnn.to_model("digits_cnn");
    let sess = Session::new(model.clone())?;
    let batches: Vec<_> = (0..96)
        .map(|i| {
            let (x, _) = train.sample(i);
            vec![(
                "x".to_string(),
                Tensor::from_f32(&[1, 1, 8, 8], x.to_vec()).unwrap(),
            )]
        })
        .collect();
    let cal = calibrate(&sess, &batches, CalibStrategy::MaxRange)?;
    let preq = quantize_model(&model, &cal, &QuantizeOptions::default())?;

    // Evaluation batch (whole test set as one NCHW tensor).
    let mut xs = Vec::with_capacity(test.len() * 64);
    for i in 0..test.len() {
        xs.extend_from_slice(test.sample(i).0);
    }
    let full = Tensor::from_f32(&[test.len(), 1, 8, 8], xs)?;

    let eval = |cfg: HwConfig| -> anyhow::Result<(f32, f64, f64, f64)> {
        let hw = HwModule::compile(&preq, cfg.clone())?;
        let (probs, cost) = hw.run(&full)?;
        let preds: Vec<usize> = probs
            .as_f32()?
            .chunks(10)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect();
        let acc =
            preds.iter().zip(&test.y).filter(|(p, y)| p == y).count() as f32 / test.len() as f32;
        let per = test.len() as f64;
        Ok((
            acc,
            cost.cycles as f64 / per,
            cost.energy_nj(&cfg) / 1000.0 / per,
            cost.utilization(&cfg),
        ))
    };

    println!("-- MAC array sweep (lut 8b, round-half-even) --");
    println!("array   | accuracy | cycles/img | uJ/img | utilization");
    for dim in [4usize, 8, 16, 32, 64] {
        let (acc, cyc, uj, util) = eval(HwConfig::default().with_array(dim, dim))?;
        println!(
            "{dim:>2}x{dim:<3} | {:>7.2}% | {:>10.0} | {:>6.3} | {:>10.1}%",
            100.0 * acc,
            cyc,
            uj,
            100.0 * util
        );
    }

    // The LUT and rounding knobs only engage on activation stages: use a
    // Tanh MLP lowered to the Fig. 4 pattern (int8 tanh via ROM) so the
    // sweep actually exercises them.
    use pqdl::rewrite::ActPrecision;
    use pqdl::train::{train_classifier, HiddenAct, Mlp};
    let mut tanh_mlp = Mlp::new(&[64, 48, 10], HiddenAct::Tanh, 600);
    train_classifier(&mut tanh_mlp, &train, 15, 32, 0.08, 0.9, 601);
    let tanh_fp32 = pqdl::train::accuracy(&tanh_mlp, &test);
    let tanh_model = tanh_mlp.to_model("digits_tanh");
    let tsess = Session::new(tanh_model.clone())?;
    let tbatches: Vec<_> = (0..96)
        .map(|i| {
            let (x, _) = train.sample(i);
            vec![("x".to_string(), Tensor::from_f32(&[1, 64], x.to_vec()).unwrap())]
        })
        .collect();
    let tcal = calibrate(&tsess, &tbatches, CalibStrategy::MaxRange)?;
    let tanh_preq = quantize_model(
        &tanh_model,
        &tcal,
        &QuantizeOptions {
            act_precision: ActPrecision::Int8,
            ..Default::default()
        },
    )?;
    let mut txs = Vec::with_capacity(test.len() * 64);
    for i in 0..test.len() {
        txs.extend_from_slice(test.sample(i).0);
    }
    let tfull = Tensor::from_f32(&[test.len(), 64], txs)?;
    let teval = |cfg: HwConfig| -> anyhow::Result<f32> {
        let hw = HwModule::compile(&tanh_preq, cfg)?;
        let (probs, _) = hw.run(&tfull)?;
        let acc = probs
            .as_f32()?
            .chunks(10)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .zip(&test.y)
            .filter(|(p, y)| p == *y)
            .count() as f32
            / test.len() as f32;
        Ok(acc)
    };

    println!(
        "\n-- activation ROM width sweep (tanh MLP, Fig. 4; fp32 ref {:.2}%) --",
        100.0 * tanh_fp32
    );
    println!("lut bits | accuracy");
    for bits in [8u32, 7, 6, 5, 4, 3, 2] {
        let acc = teval(HwConfig::default().with_lut_bits(bits))?;
        println!("{bits:>8} | {:>7.2}%", 100.0 * acc);
    }

    println!("\n-- rescale rounding mode sweep (tanh MLP) --");
    println!("rounding          | accuracy");
    for (name, r) in [
        ("half-even       ", Rounding::HalfEven),
        ("half-away-0     ", Rounding::HalfAwayFromZero),
        ("truncate        ", Rounding::Truncate),
    ] {
        let acc = teval(HwConfig::default().with_rounding(r))?;
        println!("{name} | {:>7.2}%", 100.0 * acc);
    }

    println!(
        "\nfp32 reference: {:.2}% — the model file was identical for every row above.",
        100.0 * fp32_acc
    );
    Ok(())
}
