//! End-to-end driver (the DESIGN.md E2E experiment): train a 3-layer
//! MLP on the synthetic-digits corpus, calibrate, emit the pre-quantized
//! model, execute it on every backend, and serve it through the
//! coordinator with dynamic batching — reporting accuracy, narrow-margin
//! agreement and latency/throughput.
//!
//!     cargo run --release --example digits_e2e
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use pqdl::compare::compare_quantized;
use pqdl::coordinator::{
    CoordinatorBuilder, HwSimBackend, InterpBackend, ServerConfig,
};
use pqdl::hwsim::{HwConfig, HwModule};
use pqdl::interp::Session;
use pqdl::quant::CalibStrategy;
use pqdl::rewrite::{calibrate, quantize_model, QuantizeOptions};
use pqdl::tensor::Tensor;
use pqdl::train::{accuracy, synthetic_digits, train_classifier, HiddenAct, Mlp};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn batch_of(data: &pqdl::train::Dataset, idx: &[usize]) -> Tensor {
    let mut x = Vec::with_capacity(idx.len() * data.dim);
    for &i in idx {
        x.extend_from_slice(data.sample(i).0);
    }
    Tensor::from_f32(&[idx.len(), data.dim], x).unwrap()
}

fn acc_of(outputs: &[usize], data: &pqdl::train::Dataset) -> f32 {
    outputs
        .iter()
        .zip(&data.y)
        .filter(|(p, y)| p == y)
        .count() as f32
        / data.len() as f32
}

fn argmax_rows(t: &Tensor, classes: usize) -> Vec<usize> {
    t.as_f32()
        .unwrap()
        .chunks(classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    println!("== pqdl end-to-end: synthetic digits ==\n");

    // ---- 1. Train ------------------------------------------------------
    let data = synthetic_digits(4000, 2024);
    let (train, test) = data.split(0.2, 2025);
    let mut mlp = Mlp::new(&[64, 128, 64, 10], HiddenAct::Relu, 2026);
    println!(
        "training fp32 MLP 64-128-64-10 ({} params) on {} samples...",
        mlp.param_count(),
        train.len()
    );
    let t0 = Instant::now();
    let losses = train_classifier(&mut mlp, &train, 30, 32, 0.08, 0.9, 2027);
    println!(
        "  trained in {:.1?}; loss {:.4} -> {:.4}",
        t0.elapsed(),
        losses[0],
        losses.last().unwrap()
    );
    let fp32_acc = accuracy(&mlp, &test);
    println!("  fp32 test accuracy: {:.2}%", 100.0 * fp32_acc);

    // ---- 2. Calibrate + quantize (both rescale codifications) ----------
    let model = mlp.to_model("digits_mlp");
    let sess = Session::new(model.clone())?;
    let calib_batches: Vec<_> = (0..128)
        .map(|i| {
            let (x, _) = train.sample(i);
            vec![("x".to_string(), Tensor::from_f32(&[1, 64], x.to_vec()).unwrap())]
        })
        .collect();
    let cal = calibrate(&sess, &calib_batches, CalibStrategy::MaxRange)?;

    for (label, opts) in [
        ("2-Mul (hardware-explicit)", QuantizeOptions::default()),
        (
            "1-Mul (toolchain-derived)",
            QuantizeOptions {
                two_mul: false,
                ..Default::default()
            },
        ),
    ] {
        println!("\n-- rescale codification: {label} --");
        let preq = quantize_model(&model, &cal, &opts)?;
        let bytes = pqdl::onnx::model_to_json(&preq).len();
        println!(
            "  pre-quantized model: {} nodes, {} KiB",
            preq.graph.nodes.len(),
            bytes / 1024
        );

        // ---- 3. Execute on all environments ----------------------------
        let qsess = Session::new(preq.clone())?;
        let hw = HwModule::compile(&preq, HwConfig::default())?;
        println!(
            "  hw compile: {} stages, rescales exact-from-model: {}",
            hw.stage_count(),
            hw.all_rescales_exact()
        );

        let full = batch_of(&test, &(0..test.len()).collect::<Vec<_>>());
        let interp_probs = qsess.run(&[("x", full.clone())])?.remove(0);
        let (hw_probs, cost) = hw.run(&full)?;

        let interp_acc = acc_of(&argmax_rows(&interp_probs, 10), &test);
        let hw_acc = acc_of(&argmax_rows(&hw_probs, 10), &test);
        println!(
            "  accuracy: fp32 {:.2}% | int8 interp {:.2}% | int8 hwsim {:.2}%",
            100.0 * fp32_acc,
            100.0 * interp_acc,
            100.0 * hw_acc
        );
        // Agreement measured on the int8 logits (re-quantized probs).
        let qi = interp_probs.cast(pqdl::tensor::DType::I32);
        let qh = hw_probs.cast(pqdl::tensor::DType::I32);
        let rep = compare_quantized(&qi, &qh, 8);
        println!(
            "  interp vs hwsim argmax agreement on {} samples; prob tensors exact {:.2}%",
            test.len(),
            100.0 * rep.exact_rate()
        );
        println!(
            "  hw cost/inference: {:.0} MACs, {:.0} cycles, {:.1} nJ, util {:.1}%",
            cost.macs as f64 / test.len() as f64,
            cost.cycles as f64 / test.len() as f64,
            cost.energy_nj(&HwConfig::default()) / test.len() as f64,
            100.0 * cost.utilization(&HwConfig::default())
        );
    }

    // ---- 4. Serve through the coordinator ------------------------------
    println!("\n-- serving (interp + hwsim lanes, dynamic batching) --");
    let preq = quantize_model(&model, &cal, &QuantizeOptions::default())?;
    for (mode, max_batch, max_wait_us) in
        [("batching OFF", 1usize, 1u64), ("batching ON ", 16, 200)]
    {
        let coord = CoordinatorBuilder::new(ServerConfig {
            max_batch,
            max_wait: Duration::from_micros(max_wait_us),
            // One replica: this section isolates the batching policy.
            replicas: 1,
            ..ServerConfig::default()
        })
        .register("digits", Arc::new(InterpBackend::new(preq.clone())?))
        .register(
            "digits_hw",
            Arc::new(HwSimBackend::new(&preq, HwConfig::default())?),
        )
        .start();

        let coord = Arc::new(coord);
        let n_clients = 16;
        let per_client = 100;
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..n_clients {
            let coord = coord.clone();
            let test = test.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..per_client {
                    let idx = (c * per_client + i) % test.len();
                    let x = batch_of(&test, &[idx]);
                    let resp = coord.infer("digits", x).unwrap();
                    resp.output.unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let elapsed = t0.elapsed();
        let stats = coord.metrics.snapshot("digits").unwrap();
        println!(
            "  {mode}: {} reqs in {:.2?} = {:.0} req/s | mean batch {:.2} | e2e p50 {}us p95 {}us p99 {}us",
            n_clients * per_client,
            elapsed,
            (n_clients * per_client) as f64 / elapsed.as_secs_f64(),
            stats.mean_batch(),
            stats.e2e.quantile_us(0.50),
            stats.e2e.quantile_us(0.95),
            stats.e2e.quantile_us(0.99),
        );
        coord.shutdown();
    }
    println!("\ndone.");
    Ok(())
}
