//! Serving demo: the coordinator fronting all six canonical figure
//! models with interpreter, hardware-simulator and (when artifacts are
//! built) XLA/PJRT lanes, under a mixed concurrent load.
//!
//!     make artifacts && cargo run --release --example serve_demo

use pqdl::coordinator::{
    CoordinatorBuilder, HwSimBackend, InterpBackend, PjrtBackend, ServerConfig,
};
use pqdl::figures::Figure;
use pqdl::hwsim::HwConfig;
use pqdl::runtime::PjrtService;
use pqdl::train::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let artifact_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let pjrt = if artifact_dir.join("manifest.json").exists() {
        println!("loading + compiling PJRT artifacts...");
        let svc = PjrtService::spawn(artifact_dir)?;
        let rows = svc.verify_golden()?;
        let worst = rows.iter().map(|(_, _, d)| *d).max().unwrap_or(0);
        println!(
            "  {} artifacts verified against python golden outputs (max LSB diff {})",
            rows.len(),
            worst
        );
        Some(svc)
    } else {
        println!("artifacts/ not built; PJRT lanes disabled (run `make artifacts`)");
        None
    };

    let mut builder = CoordinatorBuilder::new(ServerConfig {
        max_batch: 8,
        max_wait: Duration::from_micros(500),
        // Default `replicas: 0` = auto — the machine-level budget is
        // split across the 12-18 lanes this demo registers, so the
        // thread count stays sane without hand-tuning.
        ..ServerConfig::default()
    });
    let mut lanes = Vec::new();
    println!(
        "kernel isa: {} (host supports: {})",
        pqdl::ops::Isa::active(),
        pqdl::ops::Isa::available()
            .iter()
            .map(|i| i.name())
            .collect::<Vec<_>>()
            .join(", ")
    );
    println!("plan-time fusion coverage (interp lanes):");
    for fig in Figure::ALL {
        let model = fig.model();
        let interp = InterpBackend::new(model.clone())?;
        // Fusion coverage per lane: the paper's whole chain collapses to
        // one fused step per figure (two where an activation LUT folds).
        println!("  {:<18} {}", fig.name(), interp.plan_stats());
        builder = builder.register(&format!("{}/interp", fig.name()), Arc::new(interp));
        builder = builder.register(
            &format!("{}/hwsim", fig.name()),
            Arc::new(HwSimBackend::new(&model, HwConfig::default())?),
        );
        lanes.push(format!("{}/interp", fig.name()));
        lanes.push(format!("{}/hwsim", fig.name()));
        if let Some(svc) = &pjrt {
            builder = builder.register(
                &format!("{}/pjrt", fig.name()),
                Arc::new(PjrtBackend::new(svc.clone(), fig.name())?),
            );
            lanes.push(format!("{}/pjrt", fig.name()));
        }
    }
    let coord = Arc::new(builder.start());
    println!("serving {} lanes\n", coord.models().len());

    // Mixed load: 6 client threads, random lane + random input each.
    let n_clients = 6;
    let per_client = 150;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let coord = coord.clone();
        let lanes = lanes.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64 + 1);
            let mut errors = 0usize;
            for i in 0..per_client {
                let lane = &lanes[rng.below(lanes.len())];
                let fig_name = lane.split('/').next().unwrap();
                let fig = Figure::ALL
                    .into_iter()
                    .find(|f| f.name() == fig_name)
                    .unwrap();
                let x = fig.input(1, (c * 10_000 + i) as u64);
                match coord.infer(lane, x) {
                    Ok(resp) if resp.output.is_ok() => {}
                    _ => errors += 1,
                }
            }
            errors
        }));
    }
    let mut total_errors = 0;
    for j in joins {
        total_errors += j.join().unwrap();
    }
    let elapsed = t0.elapsed();
    let total = n_clients * per_client;
    println!(
        "{} requests in {:.2?} = {:.0} req/s ({} errors)\n",
        total,
        elapsed,
        total as f64 / elapsed.as_secs_f64(),
        total_errors
    );
    println!("{}", coord.metrics.report());
    if let Some(svc) = &pjrt {
        svc.shutdown();
    }
    coord.shutdown();
    Ok(())
}
